"""Serving-tier tests: slots, admission, parity, budget, SLOs, sampling.

The decode-parity tests are the load-bearing ones: the slot engine's
bucket-padded batch-1 prefill + vector-position decode must produce,
per request, exactly the tokens a plain scalar-position batch-1
generation produces — the continuous-batching machinery changes the
schedule, never the math.  Scheduler/SLO tests run on the
:class:`~repro.serve.SyntheticClock`, where every timestamp is exact
arithmetic over the configured op costs.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.metrics import MetricsLogger
from repro.models import (decode_step, evict_decode_state,
                          init_decode_state, init_params,
                          insert_decode_state, prefill)
from repro.models.common import ArchConfig
from repro.serve import (AdmissionPolicy, Request, RequestQueue,
                         SamplingSpec, ServeMetrics, ServeScheduler,
                         SlotEngine, SyntheticClock, bucket_len,
                         sample_token, serve_static, static_generate,
                         synthetic_requests)

CFG = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 vocab_size=64, q_chunk=64, kv_chunk=64,
                 mxu_f32_accum=False)
_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = init_params(jax.random.PRNGKey(0), CFG)
    return _PARAMS


def _reference_generate(params, prompt, max_new, cache_len):
    """Scalar-position batch-1 greedy generation (the pre-serve path)."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, state = prefill(params, CFG, {"tokens": toks},
                            extra_capacity=cache_len - len(prompt))
    out = [int(jnp.argmax(logits[0]))]
    while len(out) < max_new:
        logits, state = decode_step(
            params, CFG, state, jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def _drain(engine, reqs):
    """Drive the engine clock-free: insert in order as slots free up."""
    pending = list(reqs)
    while pending or engine.active_count:
        while pending and engine.has_free:
            engine.insert(pending.pop(0))
        engine.decode_round()


# ---------------------------------------------------------------------------
# buckets + request layer
# ---------------------------------------------------------------------------

def test_bucket_len():
    assert bucket_len(3, 64, exact=False) == 8       # floor bucket
    assert bucket_len(8, 64, exact=False) == 8
    assert bucket_len(9, 64, exact=False) == 16
    assert bucket_len(33, 64, exact=False) == 64
    assert bucket_len(100, 64, exact=False) == 64    # clamp to capacity
    assert bucket_len(13, 64, exact=True) == 13      # moe/ssm: no padding


def test_synthetic_requests_deterministic():
    a = synthetic_requests(4, vocab_size=64, prompt_len=8, prompt_jitter=3,
                           arrival_gap_s=0.5, seed=11)
    b = synthetic_requests(4, vocab_size=64, prompt_len=8, prompt_jitter=3,
                           arrival_gap_s=0.5, seed=11)
    assert [r.prompt for r in a] == [r.prompt for r in b]
    assert [r.arrival_s for r in a] == [0.0, 0.5, 1.0, 1.5]
    assert all(5 <= r.prompt_len <= 11 for r in a)


def test_admission_policy_rejects():
    pol = AdmissionPolicy(cache_len=16, max_queue=2)
    q = RequestQueue(pol)
    fits = Request(rid=0, prompt=[1] * 8, max_new_tokens=8)
    too_big = Request(rid=1, prompt=[1] * 8, max_new_tokens=9)
    assert q.push(fits) and not q.push(too_big)
    assert too_big.finish_reason == "rejected"
    assert q.rejected == [too_big]
    assert q.push(Request(rid=2, prompt=[1] * 4, max_new_tokens=4))
    overflow = Request(rid=3, prompt=[1] * 4, max_new_tokens=4)
    assert not q.push(overflow)                      # max_queue=2 bound
    assert overflow.finish_reason == "rejected"
    assert len(q) == 2


def test_queue_arrival_ordering():
    q = RequestQueue()
    for rid, t in [(0, 2.0), (1, 0.5), (2, 1.0)]:
        q.push(Request(rid=rid, prompt=[1], max_new_tokens=1, arrival_s=t))
    assert q.next_arrival_s() == 0.5
    assert q.pop_ready(0.0) is None                  # nothing has arrived
    assert q.pop_ready(1.5).rid == 1                 # earliest arrival first
    assert q.pop_ready(1.5).rid == 2
    assert q.pop_ready(1.5) is None                  # rid 0 arrives at 2.0
    assert q.pop_ready(2.0).rid == 0


# ---------------------------------------------------------------------------
# decode-state helpers + slot lifecycle
# ---------------------------------------------------------------------------

def test_insert_evict_state_helpers():
    params, cache_len = _params(), 32
    big = init_decode_state(CFG, 3, cache_len, per_slot_pos=True)
    assert big.pos.shape == (3,)
    plen = 6
    toks = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    _, one = prefill(params, CFG, {"tokens": toks},
                     extra_capacity=cache_len - plen)
    big = insert_decode_state(big, one, 1)
    assert int(big.pos[1]) == plen and int(big.pos[0]) == 0
    got = jax.tree.map(lambda b: b[:, 1], big.caches)
    want = jax.tree.map(lambda s: s[:, 0], one.caches)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    big = evict_decode_state(big, 1)
    assert int(big.pos[1]) == 0
    assert all(not np.asarray(leaf[:, 1]).any()
               for leaf in jax.tree.leaves(big.caches))


def test_slot_insert_retire_reuse():
    engine = SlotEngine(_params(), CFG, slots=2, cache_len=32)
    r0 = Request(rid=0, prompt=[3, 4, 5], max_new_tokens=2)
    r1 = Request(rid=1, prompt=[6, 7], max_new_tokens=4)
    engine.insert(r0)
    engine.insert(r1)
    assert not engine.has_free and engine.active_count == 2
    assert {r0.slot, r1.slot} == {0, 1}
    finished = engine.decode_round()                 # r0 hits its budget
    assert finished == [r0] and r0.finish_reason == "length"
    assert len(r0.out_tokens) == 2
    assert engine.has_free and engine.active_count == 1
    r2 = Request(rid=2, prompt=[9, 10, 11, 12], max_new_tokens=2)
    engine.insert(r2)
    assert r2.slot == r0.slot                        # freed slot reused
    while engine.active_count:
        engine.decode_round()
    assert r1.done and r2.done
    assert len(r1.out_tokens) == 4 and len(r2.out_tokens) == 2


def test_slot_engine_rejects_unservable():
    sliding = dataclasses.replace(CFG, sliding_window=8)
    with pytest.raises(NotImplementedError):
        SlotEngine(_params(), sliding, slots=1, cache_len=16)
    engine = SlotEngine(_params(), CFG, slots=1, cache_len=16)
    with pytest.raises(ValueError):                  # can never fit the slot
        engine.insert(Request(rid=0, prompt=[1] * 10, max_new_tokens=8))


# ---------------------------------------------------------------------------
# parity: continuous batching == static batch == scalar-pos reference
# ---------------------------------------------------------------------------

def test_continuous_matches_static_and_reference():
    """Heterogeneous prompts through 2 slots (forcing reuse) produce the
    same tokens as the static batch AND the plain scalar-position loop —
    bucket padding, slot scatter, and vector positions are invisible."""
    params, cache_len = _params(), 32
    prompts = [[5, 9, 2], [7, 1, 1, 3, 8, 2, 4], [11, 13], [6] * 9,
               [40, 41, 42, 43, 44]]
    new = [4, 6, 3, 5, 4]
    mk = lambda: [Request(rid=i, prompt=list(p), max_new_tokens=n)  # noqa: E731
                  for i, (p, n) in enumerate(zip(prompts, new))]
    cont = mk()
    engine = SlotEngine(params, CFG, slots=2, cache_len=cache_len)
    _drain(engine, cont)
    assert len(engine._prefill_cache) <= 3           # buckets, not lengths
    stat = static_generate(params, CFG, mk(), cache_len=cache_len)
    for c, s, p, n in zip(cont, stat, prompts, new):
        ref = _reference_generate(params, p, n, cache_len)
        assert c.out_tokens == ref, (c.rid, c.out_tokens, ref)
        assert s.out_tokens == ref, (s.rid, s.out_tokens, ref)


# ---------------------------------------------------------------------------
# scheduler: staggered admission, budget accounting, exact SLOs
# ---------------------------------------------------------------------------

class _StubSource:
    def batch(self, i):
        return i


class _StubSession:
    """Just enough AMBSession surface for the scheduler's train path."""

    def __init__(self, params):
        self.params = params
        self.steps_done = 0

    def batch_source(self):
        return _StubSource()

    def step(self, batch):
        self.steps_done += 1
        return {"loss": 1.0 / self.steps_done}


def test_scheduler_staggered_admission():
    reqs = synthetic_requests(5, vocab_size=CFG.vocab_size, prompt_len=6,
                              prompt_jitter=2, max_new_tokens=3,
                              arrival_gap_s=0.2, seed=2)
    queue = RequestQueue(AdmissionPolicy(cache_len=32))
    for r in reqs:
        queue.push(r)
    engine = SlotEngine(_params(), CFG, slots=2, cache_len=32)
    clock = SyntheticClock(prefill_tok_s=0.001, decode_round_s=0.01)
    report = ServeScheduler(engine, queue, round_budget_s=0.1,
                            clock=clock).run()
    assert report.summary["n_requests"] == 5
    for r in reqs:
        assert r.admit_s >= r.arrival_s              # never admit early
        assert r.first_token_s == pytest.approx(
            r.admit_s + 0.001 * r.prompt_len)        # TTFT = queue + prefill
    admits = [r.admit_s for r in reqs]
    assert admits == sorted(admits)                  # arrival order held


def test_scheduler_budget_and_slo_exact():
    """One request on a synthetic clock: every timestamp, SLO, and train
    charge is exact budget arithmetic (8-token prefill at 0.01/tok, two
    decode rounds at 0.1, then two 0.3 train epochs fill the 1.0 round)."""
    stub = _StubSession(_params())
    queue = RequestQueue(AdmissionPolicy(cache_len=32))
    queue.push(Request(rid=0, prompt=[1] * 8, max_new_tokens=3))
    engine = SlotEngine(stub.params, CFG, slots=2, cache_len=32)
    clock = SyntheticClock(prefill_tok_s=0.01, decode_round_s=0.1,
                           train_epoch_s=0.3)
    sched = ServeScheduler(engine, queue, round_budget_s=1.0, clock=clock,
                           session=stub, train_epochs=2)
    report = sched.run()
    req = report.requests[0]
    assert req.first_token_s == pytest.approx(0.08)
    assert req.finish_s == pytest.approx(0.28)       # + 2 decode rounds
    s = report.summary
    assert s["ttft_p50_s"] == pytest.approx(0.08)
    assert s["tpot_p50_s"] == pytest.approx(0.1)     # (0.28-0.08)/(3-1)
    assert s["latency_p99_s"] == pytest.approx(0.28)
    assert s["tokens_per_s"] == pytest.approx(3 / 0.28)
    # leftover budget absorbed exactly two epochs: 0.28+0.3+0.3 <= 1.0
    assert report.train_epochs == 2 and stub.steps_done == 2
    assert clock.now() == pytest.approx(0.88)
    assert sched.metrics.train_losses == [1.0, 0.5]
    # mandatory refresh: engine decodes the post-step params object
    assert engine.params is stub.params


def test_scheduler_train_backs_off_under_load():
    """With a known epoch cost that never fits the leftover budget, zero
    epochs run; relaxing the budget on the same workload absorbs them."""
    def lane(budget, known_cost):
        stub = _StubSession(_params())
        queue = RequestQueue(AdmissionPolicy(cache_len=32))
        for i in range(3):
            queue.push(Request(rid=i, prompt=[2] * 8, max_new_tokens=3,
                               arrival_s=0.1 * i))
        engine = SlotEngine(stub.params, CFG, slots=1, cache_len=32)
        sched = ServeScheduler(
            engine, queue, round_budget_s=budget,
            clock=SyntheticClock(prefill_tok_s=0.01, decode_round_s=0.1),
            session=stub, train_epochs=4)
        sched._train_cost = known_cost               # pre-learned estimate
        return sched.run()

    assert lane(0.3, known_cost=0.5).train_epochs == 0
    assert lane(5.0, known_cost=0.5).train_epochs == 4


def test_serve_static_barrier_costs():
    """The static lane's TTFT includes the group barrier: the first
    arrival waits for the last member of its group."""
    reqs = [Request(rid=i, prompt=[3] * 4, max_new_tokens=2,
                    arrival_s=0.5 * i) for i in range(4)]
    clock = SyntheticClock(prefill_tok_s=0.01, decode_round_s=0.1)
    report = serve_static(_params(), CFG, reqs, batch=4, cache_len=16,
                          clock=clock)
    assert report.summary["n_requests"] == 4
    # group barriers on the last arrival (t=1.5) + 16 prefill tokens
    assert reqs[0].first_token_s == pytest.approx(1.5 + 0.16)
    assert reqs[0].first_token_s == reqs[3].first_token_s


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_controls():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
    greedy = sample_token(logits)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), -1))
    # top_k=1 at any temperature collapses to argmax
    np.testing.assert_array_equal(
        np.asarray(sample_token(logits, key, temperature=1.5, top_k=1)),
        np.asarray(greedy))
    with pytest.raises(ValueError):
        sample_token(logits, temperature=0.7)        # stochastic needs key
    # top-k restricts support to the k best ids per row
    top5 = np.argsort(np.asarray(logits), -1)[:, -5:]
    for i in range(20):
        got = np.asarray(sample_token(logits, jax.random.fold_in(key, i),
                                      temperature=1.0, top_k=5))
        assert all(got[r] in top5[r] for r in range(3))
    assert SamplingSpec().greedy and not SamplingSpec(temperature=0.7).greedy


def test_sampling_seeded_determinism():
    """Same SamplingSpec seed => the engine replays the same tokens."""
    def run(seed):
        engine = SlotEngine(
            _params(), CFG, slots=2, cache_len=32,
            sampling=SamplingSpec(temperature=0.9, top_k=8, seed=seed))
        reqs = [Request(rid=i, prompt=[7, 8, 9 + i], max_new_tokens=6)
                for i in range(3)]
        _drain(engine, reqs)
        return [r.out_tokens for r in reqs]

    assert run(5) == run(5)
    runs = {tuple(map(tuple, run(s))) for s in (5, 6, 7)}
    assert len(runs) > 1                             # seed actually matters


# ---------------------------------------------------------------------------
# metrics plane
# ---------------------------------------------------------------------------

def test_serve_metrics_records_and_logger(tmp_path):
    path = tmp_path / "serve.jsonl"
    metrics = ServeMetrics(MetricsLogger(str(path)))
    req = Request(rid=0, prompt=[1, 2], max_new_tokens=3, arrival_s=1.0,
                  admit_s=1.5, first_token_s=2.0, finish_s=4.0,
                  out_tokens=[3, 4, 5], finish_reason="length")
    rec = metrics.complete(req)
    assert rec["ttft_s"] == pytest.approx(1.0)
    assert rec["tpot_s"] == pytest.approx(1.0)       # (4.0-2.0)/(3-1)
    assert rec["queue_s"] == pytest.approx(0.5)
    metrics.train_step(0, 2.5)
    # per-write flush: both records are on disk before any close
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["kind"] for ln in lines] == ["request", "train"]
    s = metrics.summary()
    assert s["n_requests"] == 1 and s["total_tokens"] == 3
    assert s["span_s"] == pytest.approx(3.0)         # arrival -> finish
    assert s["train_loss_last"] == 2.5
    metrics.logger.close()
    metrics.logger.close()                           # idempotent close
