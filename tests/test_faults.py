"""Straggler-proof fleets: coded redundancy, survivor relayout, churn.

Covers the three robustness layers this repo adds on top of the paper's
b_i(t) = 0 wipeout tolerance:

  * :mod:`repro.dist.redundancy` — coded data placement + the
    decode-on-settle weights: unbiasedness (every covered sample totals
    weight one across its replica holders), bit-exactness of the
    uncoded path against ``seq_weights_from_b``, placement validation.
  * :mod:`repro.dist.consensus` elastic membership — operator
    properties of both the survivor-relayout taps (doubly stochastic,
    positive spectral gap, inactive rows exactly identity, combine ==
    dense matrix power) and the legacy dense ``masked_metropolis``
    fallback; the single-survivor identity and all-inactive rejection
    edge cases; dense-vs-relayout A/B agreement on the survivor mean.
  * :mod:`repro.faults` — determinism and composition of the fault
    models, injector actuation (events only on membership change,
    quorum guard, slowdown pinning), and — slow marked — dual-state
    preservation across leave -> rejoin on a real mesh, including the
    async D > 1 drain-first flush, plus the compiled-HLO check that
    churned ring steps stay on the collective-permute fast path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist import (CodedAssignment, SurvivorTaps, epoch_weights,
                        make_strategy, masked_metropolis, survivor_taps)
from repro.dist.amb import seq_weights_from_b
from repro.faults import (Compose, CorrelatedOutage, FailSlow, FailStop,
                          FaultInjector, PoissonChurn)

from test_dist import run_sub


# ---------------------------------------------------------------------------
# Coded redundancy: placement + decode weights
# ---------------------------------------------------------------------------

def test_coded_assignment_validation():
    with pytest.raises(ValueError):
        CodedAssignment(8, 3)                # rho must divide n
    with pytest.raises(ValueError):
        CodedAssignment(8, 0)                # rho >= 1
    a = CodedAssignment(8, 2)
    assert a.groups == 4
    assert [a.group(i) for i in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    # epoch_weights rejects a mismatched fleet size
    with pytest.raises(ValueError):
        epoch_weights(jnp.zeros(4, jnp.int32), 4, 2, CodedAssignment(8, 2))


def test_rotated_replicas_stagger_within_group():
    """Members of a group start their sweep at rotated offsets, so a
    half-finished group still covers the whole block (the point of the
    rotation — identical placement would re-cover the same prefix)."""
    a = CodedAssignment(8, 4)
    per = 8
    assert a.shifts(per)[:4].tolist() == [0, 2, 4, 6]
    # every worker in a group reads the group's stream node
    assert a.data_nodes()[:4].tolist() == [0, 0, 0, 0]


def test_uncoded_epoch_weights_bit_exact():
    """rho = 1 (and assignment=None) must reproduce the paper's eq.-3
    weights and effective batch bit-for-bit — coded support cannot
    perturb the uncoded fast path."""
    n, per = 4, 8
    b = jnp.asarray([0, 3, 8, 11], jnp.int32)     # incl. the per-cap case
    for a in (None, CodedAssignment(n, 1)):
        sw, bw = epoch_weights(b, n, per, a)
        ref = seq_weights_from_b(b, n * per, n).reshape(n, per)
        np.testing.assert_array_equal(np.asarray(sw), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(bw),
                                      np.minimum(np.asarray(b), per))


def test_decode_weights_unbiased_property():
    """The decode invariant: for every *covered* block sample the decode
    weights across its replica holders sum to exactly 1 (unbiased full
    gradient over the covered set); uncovered samples get weight 0."""
    rng = np.random.default_rng(0)
    for n, rho, per in [(8, 2, 4), (8, 4, 8), (6, 3, 5), (12, 2, 7)]:
        a = CodedAssignment(n, rho)
        shifts = a.shifts(per)
        for _ in range(10):
            b = rng.integers(0, per + 2, size=n)
            sw, bw = epoch_weights(jnp.asarray(b, jnp.int32), n, per, a)
            sw = np.asarray(sw)
            np.testing.assert_allclose(np.asarray(bw), sw.sum(1), rtol=1e-6)
            # scatter local weights back to block coordinates
            block_w = np.zeros((a.groups, per))
            covered = np.zeros((a.groups, per), dtype=bool)
            for i in range(n):
                g = a.group(i)
                for s in range(min(b[i], per)):
                    blk = (s + shifts[i]) % per
                    block_w[g, blk] += sw[i, s]
                    covered[g, blk] = True
            np.testing.assert_allclose(block_w[covered], 1.0, rtol=1e-6)
            assert (block_w[~covered] == 0.0).all()


def test_decode_single_survivor_recovers_full_block():
    """One full-batch survivor per group reconstructs the block alone at
    weight 1 — a dead replica holder costs no data, only redundancy."""
    n, rho, per = 8, 2, 4
    b = jnp.asarray([per, 0] * 4, jnp.int32)
    sw, bw = epoch_weights(b, n, per, CodedAssignment(n, rho))
    np.testing.assert_array_equal(np.asarray(sw)[0::2], 1.0)
    np.testing.assert_array_equal(np.asarray(sw)[1::2], 0.0)
    np.testing.assert_array_equal(np.asarray(bw), [per, 0] * 4)


def test_decode_double_coverage_halves_weights():
    n, rho, per = 4, 2, 4
    sw, bw = epoch_weights(jnp.full(4, per, jnp.int32), n, per,
                           CodedAssignment(n, rho))
    np.testing.assert_allclose(np.asarray(sw), 0.5)
    np.testing.assert_allclose(np.asarray(bw), per / 2)


# ---------------------------------------------------------------------------
# Elastic membership: survivor taps + dense fallback operator properties
# ---------------------------------------------------------------------------

def _spectral_gap(p, active):
    """1 - |second eigenvalue| of the operator restricted to survivors."""
    act = np.asarray(active)
    sub = np.asarray(p)[np.ix_(act, act)]
    ev = np.sort(np.abs(np.linalg.eigvals(sub)))[::-1]
    assert abs(ev[0] - 1.0) < 1e-6           # f32 tap weights
    return 1.0 - ev[1] if len(ev) > 1 else 1.0


@pytest.mark.parametrize("graph,n", [("ring", 8), ("torus", 12)])
def test_survivor_taps_operator_properties(graph, n):
    rng = np.random.default_rng(1)
    for _ in range(8):
        active = rng.random(n) > 0.4
        if active.sum() < 2:
            active[:2] = True
        taps = survivor_taps(tuple(active), graph)
        assert isinstance(taps, SurvivorTaps)
        p = taps.dense()
        # rows/cols sum to 1, non-negative: a doubly stochastic operator
        np.testing.assert_allclose(p.sum(0), 1.0, atol=1e-6)
        np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-6)
        assert (p >= -1e-12).all()
        # inactive rows/cols are exactly identity (state frozen)
        for i in np.flatnonzero(~active):
            want = np.zeros(n)
            want[i] = 1.0
            np.testing.assert_array_equal(p[i], want)
            np.testing.assert_array_equal(p[:, i], want)
        # survivors form a connected re-laid ring/torus: gap > 0
        assert _spectral_gap(p, active) > 1e-6
        # take() applies the dense operator on the survivor rows (the
        # inactive rows are restored to identity by combine's final
        # mask, not by the taps themselves)
        x = rng.standard_normal((n, 5)).astype(np.float32)
        got = sum(np.asarray(taps.weights[i]) * np.asarray(
            taps.take(jnp.asarray(x), i)) for i in range(taps.k))
        np.testing.assert_allclose(got[active], (p @ x)[active], atol=1e-5)


def test_masked_metropolis_operator_properties():
    """The dense fallback keeps the same contract on the *induced*
    subgraph: doubly stochastic, frozen inactive rows, positive gap on
    connected survivor sets, loud failure on disconnected ones."""
    from repro.core import consensus as cns
    adj = cns.build_graph("ring", 8)
    p = masked_metropolis(adj, (True, True, True, False, True,
                               True, True, True), lazy=0.5)
    np.testing.assert_allclose(p.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-12)
    np.testing.assert_array_equal(p[3], np.eye(8)[3])
    active = np.ones(8, bool)
    active[3] = False
    assert _spectral_gap(p, active) > 1e-6
    # two non-adjacent failures disconnect a ring's induced subgraph
    with pytest.raises(ValueError, match="disconnect"):
        masked_metropolis(adj, (True, True, False, True, True,
                                False, True, True), lazy=0.5)


def test_relayout_reconnects_what_masking_disconnects():
    """The mask that kills the induced-subgraph ring is exactly where
    relayout earns its keep: survivors re-enumerate onto a fresh ring,
    gossip converges to the survivor mean anyway."""
    n = 8
    active = (True, True, False, True, True, False, True, True)
    msgs = jax.random.normal(jax.random.PRNGKey(1), (n, 16))
    g = make_strategy("gossip", n, rounds=400, graph="ring", active=active)
    assert isinstance(g.taps, SurvivorTaps)
    out = np.asarray(g.combine(msgs))
    act = np.asarray(active)
    want = np.asarray(msgs)[act].mean(0)
    np.testing.assert_allclose(out[act],
                               np.broadcast_to(want, out[act].shape),
                               atol=1e-5)
    np.testing.assert_array_equal(out[~act], np.asarray(msgs)[~act])
    # the legacy dense fallback (relayout off) refuses this mask
    with pytest.raises(ValueError, match="disconnect"):
        make_strategy("gossip", n, rounds=4, graph="ring", active=active,
                      relayout=False)


def test_relayout_and_dense_fallback_agree_on_survivor_mean():
    """A/B: on a mask both operators accept, they reach the same fixed
    point (the survivor mean) — relayout changes the mixing path, not
    the answer."""
    n = 6
    active = (True, True, True, False, True, True)
    msgs = jax.random.normal(jax.random.PRNGKey(2), (n, 8))
    fast = make_strategy("gossip", n, rounds=300, graph="ring",
                         active=active)
    dense = make_strategy("gossip", n, rounds=300, graph="ring",
                          active=active, relayout=False)
    assert isinstance(fast.taps, SurvivorTaps) and dense.taps is None
    np.testing.assert_allclose(np.asarray(fast.combine(msgs)),
                               np.asarray(dense.combine(msgs)), atol=1e-4)


def test_quantized_survivor_path_is_finite_and_identity_on_dropped():
    n = 8
    active = (True, False, True, True, True, False, True, True)
    msgs = jax.random.normal(jax.random.PRNGKey(3), (n, 32))
    g = make_strategy("gossip_q8", n, rounds=2, graph="ring",
                      active=active)
    assert isinstance(g.taps, SurvivorTaps)
    out = np.asarray(g.combine(msgs, key=jax.random.PRNGKey(0)))
    assert np.isfinite(out).all()
    act = np.asarray(active)
    np.testing.assert_array_equal(out[~act], np.asarray(msgs)[~act])


def test_single_survivor_degenerates_to_identity():
    """S1: one survivor means there is nobody to gossip with — the
    strategy must be the exact identity (no permutes, no quantization
    noise), for the fp32 and the quantized planes alike."""
    n = 4
    active = (False, False, True, False)
    msgs = jax.random.normal(jax.random.PRNGKey(4), (n, 8))
    for name in ("gossip", "gossip_q8", "gossip_q4"):
        g = make_strategy(name, n, rounds=6, graph="ring", active=active)
        assert g.identity and g.taps is None
        out = np.asarray(g.combine(msgs, key=jax.random.PRNGKey(1)))
        np.testing.assert_array_equal(out, np.asarray(msgs))


def test_all_inactive_fleet_is_rejected():
    """S1: an all-down fleet has no consensus operator — loud error,
    not a silent NaN factory."""
    for name in ("gossip", "gossip_q8"):
        with pytest.raises(ValueError, match="at least one worker"):
            make_strategy(name, 4, rounds=2, graph="ring",
                          active=(False,) * 4)


def test_survivor_taps_declines_non_circulant_cases():
    assert survivor_taps((True, False, False, False)) is None   # 1 alive
    assert survivor_taps((True, True, True), graph="star") is None


# ---------------------------------------------------------------------------
# Fault models
# ---------------------------------------------------------------------------

def test_fail_stop_window():
    m = FailStop(workers=(1, 3), at=2, until=5)
    assert m.fleet(1, 4).active.all()
    st = m.fleet(3, 4)
    np.testing.assert_array_equal(st.active, [True, False, True, False])
    assert m.fleet(5, 4).active.all()
    assert not st.healthy and m.fleet(0, 4).healthy


def test_fail_slow_multiplies_clock_draws():
    m = FailSlow(workers=(0,), factor=3.0, start=1, stop=4)
    assert m.fleet(0, 2).slow.tolist() == [1.0, 1.0]
    assert m.fleet(2, 2).slow.tolist() == [3.0, 1.0]
    assert m.fleet(2, 2).active.all()       # slow, not gone
    assert m.fleet(4, 2).healthy


def test_correlated_outage_periodicity():
    m = CorrelatedOutage(group=(0, 1), period=4, duration=2, start=2)
    downs = [not m.fleet(e, 4).active[0] for e in range(12)]
    assert downs == [False, False, True, True, False, False,
                     True, True, False, False, True, True]


def test_compose_ands_membership_and_multiplies_slowdowns():
    m = Compose((FailStop(workers=(2,), at=0),
                 FailSlow(workers=(0,), factor=2.0),
                 FailSlow(workers=(0,), factor=3.0)))
    st = m.fleet(0, 4)
    np.testing.assert_array_equal(st.active, [True, True, False, True])
    assert st.slow[0] == 6.0


def test_poisson_churn_is_pure_and_pins_quorum():
    m = PoissonChurn(leave_rate=0.5, rejoin_rate=0.5, seed=7, pin=2)
    n = 6
    traj = [m.fleet(e, n).active.copy() for e in range(40)]
    # pure in epoch: re-query gives the identical trajectory
    for e in (0, 13, 39):
        np.testing.assert_array_equal(m.fleet(e, n).active, traj[e])
    # pinned workers never leave; churned ones actually churn both ways
    assert all(t[:2].all() for t in traj)
    flat = np.stack(traj)[:, 2:]
    assert (~flat).any() and flat.any()
    transitions = (flat[1:] != flat[:-1]).sum()
    assert transitions >= 4
    # a different seed gives a different trajectory
    other = PoissonChurn(leave_rate=0.5, rejoin_rate=0.5, seed=8, pin=2)
    assert any(not np.array_equal(other.fleet(e, n).active, traj[e])
               for e in range(40))


# ---------------------------------------------------------------------------
# FaultInjector actuation
# ---------------------------------------------------------------------------

class _StubSession:
    n_workers = 4

    def __init__(self):
        self.active_calls, self.slow_calls = [], []

    def set_active(self, active):
        self.active_calls.append(np.asarray(active).copy())

    def set_slowdown(self, slow):
        self.slow_calls.append(None if slow is None
                               else np.asarray(slow).copy())


def test_injector_actuates_only_on_change():
    sess = _StubSession()
    inj = FaultInjector(FailStop(workers=(1,), at=2, until=4))
    for e in range(6):
        inj.apply(sess, e)
    # all-up at 0 is a change from "never applied"; then down at 2, up at 4
    assert len(sess.active_calls) == 3
    np.testing.assert_array_equal(sess.active_calls[1],
                                  [True, False, True, True])
    assert inj.membership_changes == 3
    assert [ev["epoch"] for ev in inj.events] == [0, 2, 4]


def test_injector_quorum_guard_keeps_worker_zero():
    sess = _StubSession()
    inj = FaultInjector(FailStop(workers=(0, 1, 2, 3), at=0))
    inj.apply(sess, 0)
    np.testing.assert_array_equal(sess.active_calls[0],
                                  [True, False, False, False])


def test_injector_slowdown_pinning():
    sess = _StubSession()
    inj = FaultInjector(FailSlow(workers=(2,), factor=4.0, start=1, stop=2))
    for e in range(3):
        inj.apply(sess, e)
    # nominal -> [1,1,4,1] -> nominal; nominal is pinned as None
    assert sess.slow_calls[0] is None
    np.testing.assert_array_equal(sess.slow_calls[1], [1, 1, 4, 1])
    assert sess.slow_calls[2] is None


def test_session_set_slowdown_validation():
    from test_api import _tiny_session
    session, _ = _tiny_session()
    with pytest.raises(ValueError):
        session.set_slowdown([1.0, 1.0])     # wrong length (n = 1)
    with pytest.raises(ValueError):
        session.set_slowdown([0.0])          # must be positive
    session.set_slowdown([2.5])
    assert session._slow is not None
    session.set_slowdown(None)
    assert session._slow is None
    session.close()


# ---------------------------------------------------------------------------
# Mesh integration (slow): state across leave -> rejoin, fast-path HLO
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_leave_rejoin_preserves_dual_state_async_drain():
    """Leave -> rejoin on a real 8-device mesh under AMB-DG staleness 2:
    set_active drains the in-flight queue first (payloads settle under
    the operator they were packed for), the departed worker's dual is
    bit-frozen while down, and it resumes from that state on rejoin."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.data import LMTokenStream

        SEQ, BPW = 32, 2
        sess = AMBSession(
            TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=SEQ,
                      batch_per_worker=BPW, data=8),
            ClockSpec(kind="simulated"),
            ConsensusSpec(consensus="gossip", gossip_rounds=3,
                          async_epochs=True, staleness=2))
        stream = LMTokenStream(vocab_size=sess.cfg.vocab_size,
                               seq_len=SEQ, seed=0)
        for e in range(3):                    # fill the staleness queue
            sess.step(stream.batch(0, e, sess.global_batch))

        mask = [True] * 8
        mask[5] = False
        sess.set_active(mask)                 # drains in-flight payloads
        z_frozen = [np.asarray(z)[5].copy()
                    for z in jax.tree.leaves(sess.state["z"])]
        for e in range(3, 5):
            m = sess.step(stream.batch(0, e, sess.global_batch))
            assert m["b"][5] == 0
        for zf, z in zip(z_frozen, jax.tree.leaves(sess.state["z"])):
            np.testing.assert_array_equal(zf, np.asarray(z)[5])
        print("FROZEN_OK")

        sess.set_active([True] * 8)           # rejoin from the stale dual
        m = sess.step(stream.batch(0, 5, sess.global_batch))
        assert m["b"][5] > 0
        # the drain emptied the queue, so this step only ENQUEUES its
        # payload (1 in flight < D=2) — flush settles it before we
        # measure that the rejoined dual resumed moving
        sess.flush()
        moved = max(float(np.abs(np.asarray(z)[5] - zf).max())
                    for zf, z in zip(z_frozen,
                                     jax.tree.leaves(sess.state["z"])))
        assert moved > 0.0
        print("REJOIN_OK")
    """)
    assert "FROZEN_OK" in out and "REJOIN_OK" in out


@pytest.mark.slow
def test_churned_ring_combine_stays_on_permute_fast_path():
    """Acceptance check: the compiled combine for a churned ring mask
    contains collective-permutes and never materializes the worker axis
    — the survivor relayout keeps elastic membership off the dense
    ``P @ m`` fallback, which compiles to an all-gather of all n
    messages followed by a dot over the worker axis."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import make_strategy

        mesh = jax.make_mesh((8,), ("data",))
        active = (True, True, False, True, True, False, True, True)
        for name in ("gossip", "gossip_q8"):
            g = make_strategy(name, 8, rounds=2, graph="ring",
                              active=active)
            sh = NamedSharding(mesh, P("data"))
            fn = jax.jit(lambda m: g.combine(m, key=jax.random.PRNGKey(0)),
                         in_shardings=sh, out_shardings=sh)
            hlo = fn.lower(
                jax.ShapeDtypeStruct((8, 256), jnp.float32)).compile()
            txt = hlo.as_text()
            assert "collective-permute" in txt, name
            assert "all-gather" not in txt, name
            print("FAST_PATH_OK", name)

        # A/B: relayout=False on a *connected* mask compiles the dense
        # operator instead — all-gather + worker-axis dot, no permutes
        g = make_strategy("gossip", 8, rounds=2, graph="ring",
                          active=(True,) * 7 + (False,), relayout=False)
        sh = NamedSharding(mesh, P("data"))
        txt = jax.jit(g.combine, in_shardings=sh, out_shardings=sh).lower(
            jax.ShapeDtypeStruct((8, 256), jnp.float32)).compile().as_text()
        assert "all-gather" in txt and "collective-permute" not in txt
        print("DENSE_FALLBACK_OK")
    """)
    assert out.count("FAST_PATH_OK") == 2 and "DENSE_FALLBACK_OK" in out


@pytest.mark.slow
def test_session_under_poisson_churn_trains_and_restores_bit_exact():
    """End to end on 8 devices: Poisson churn + coded redundancy keeps
    every loss finite, and a mid-churn save -> restore -> continue run
    reproduces the uninterrupted run bit-for-bit (fault models are pure
    in the epoch index, so the trajectory replays)."""
    out = run_sub("""
        import tempfile
        import numpy as np
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.faults import FaultInjector, PoissonChurn

        train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=16,
                          batch_per_worker=2, data=8, redundancy=2)
        cons = ConsensusSpec(consensus="gossip", gossip_rounds=2)
        model = PoissonChurn(leave_rate=0.4, rejoin_rate=0.6, seed=5)

        def fresh():
            return AMBSession(train, ClockSpec(kind="simulated"), cons)

        ref, losses = fresh(), []
        ref.run(6, faults=FaultInjector(model), prefetch=0,
                on_step=lambda s, m: losses.append(float(m["loss"])))
        assert np.isfinite(losses).all() and len(losses) == 6
        inj = FaultInjector(model)
        sess = fresh()
        sess.run(3, faults=inj, prefetch=0)
        assert inj.membership_changes >= 1
        with tempfile.TemporaryDirectory() as d:
            sess.save(d)
            resumed = AMBSession.restore(d)
        got = []
        resumed.run(3, faults=FaultInjector(model), prefetch=0,
                    on_step=lambda s, m: got.append(float(m["loss"])))
        assert got == losses[3:], (got, losses[3:])
        print("CHURN_RESTORE_OK", losses)
    """)
    assert "CHURN_RESTORE_OK" in out
