"""Beyond-paper extensions: pipelined AMB, quantized gossip, adaptive-T."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BetaSchedule, EngineConfig, ShiftedExponential, run_amb
from repro.core.consensus import build_graph, metropolis_weights
from repro.core.extensions import (AdaptiveBudget, gossip_quantized,
                                   quantize_unbiased, run_amb_adaptive,
                                   run_amb_pipelined, run_amb_quantized)
from repro.core.objectives import LinearRegression
from repro.core.stragglers import amb_budget_from_fmb


def _setup(n=10, b_global=600, d=64):
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (d,))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    t = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t, comm_time=0.3 * t,
        fmb_batch_per_node=b_global // n, graph="paper",
        consensus_rounds=5, beta=BetaSchedule(k=1.0, mu=float(b_global)))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    return obj, w_star, model, cfg, eval_fn


def test_pipelined_amb_more_samples_same_time():
    """Pipelining harvests the comm-window gradients: strictly more samples
    per epoch at identical wall time, and at least as good a final loss."""
    obj, w_star, model, cfg, eval_fn = _setup()
    kw = dict(epochs=60, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_base = run_amb(obj, model, cfg, **kw)
    h_pipe = run_amb_pipelined(obj, model, cfg, **kw)

    # identical wall clock (overlap reclaims idle cycles, adds no time)
    np.testing.assert_allclose(np.asarray(h_pipe.wall_time),
                               np.asarray(h_base.wall_time), rtol=1e-6)
    # more samples consumed (a_i(t-1) harvested from epoch 2 onward)
    assert float(h_pipe.global_batch[1:].mean()) > \
        float(h_base.global_batch[1:].mean()) * 1.1
    # no loss degradation from staleness-1 (same-or-better final eval)
    tail_pipe = float(h_pipe.eval_loss[-10:].mean())
    tail_base = float(h_base.eval_loss[-10:].mean())
    assert tail_pipe <= tail_base * 1.05


def test_pipelined_first_epoch_matches_amb():
    """Epoch 1 has no stale gradients -> identical global batch to AMB."""
    obj, w_star, model, cfg, eval_fn = _setup()
    kw = dict(epochs=3, key=jax.random.PRNGKey(1), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_base = run_amb(obj, model, cfg, **kw)
    h_pipe = run_amb_pipelined(obj, model, cfg, **kw)
    assert int(h_pipe.global_batch[0]) == int(h_base.global_batch[0])


@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_quantize_unbiased_bounds(bits, seed):
    """q(x) stays within the row's [min, max] range and is unbiased-ish."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 257))
    qs = jnp.stack([
        quantize_unbiased(x, bits, jax.random.fold_in(key, i))
        for i in range(64)])
    lo = x.min(axis=-1, keepdims=True)
    hi = x.max(axis=-1, keepdims=True)
    assert bool((qs >= lo - 1e-5).all()) and bool((qs <= hi + 1e-5).all())
    err = jnp.abs(qs.mean(0) - x).max()
    step = float(((hi - lo) / (2 ** bits - 1)).max())
    assert float(err) < step  # empirical mean within one level


def test_quantized_gossip_converges_to_mean():
    """With enough rounds, quantized gossip approaches the true average
    (quantization noise shrinks with the dynamic range)."""
    p = jnp.asarray(metropolis_weights(build_graph("paper", 10)), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (10, 128)) * 5.0
    out = gossip_quantized(x, p, rounds=60, bits=8,
                           key=jax.random.PRNGKey(3))
    target = x.mean(0)
    err = float(jnp.abs(out - target[None]).max())
    spread = float(x.max() - x.min())
    assert err < 0.02 * spread


def test_quantized_amb_lower_eps_at_same_budget():
    """8-bit gossip: 4x rounds in the same T_c -> smaller consensus eps
    than fp32 gossip, and no worse final loss."""
    obj, w_star, model, cfg, eval_fn = _setup()
    kw = dict(epochs=40, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_fp = run_amb(obj, model, cfg, **kw)
    h_q8 = run_amb_quantized(obj, model, cfg, bits=8, **kw)
    eps_fp = float(h_fp.consensus_eps[5:].mean())
    eps_q8 = float(h_q8.consensus_eps[5:].mean())
    assert eps_q8 < eps_fp
    assert float(h_q8.eval_loss[-5:].mean()) <= \
        float(h_fp.eval_loss[-5:].mean()) * 1.1


def test_adaptive_budget_tracks_drift():
    """Cluster slows down 3x mid-run: adaptive-T re-centres the global batch
    on target while fixed-T's batch collapses."""
    obj, w_star, model, cfg, eval_fn = _setup()
    target = int(600)

    def model_fn(t):
        lam = 2 / 3 if t <= 30 else 2 / 9   # 3x slower after epoch 30
        return ShiftedExponential(lam=lam, zeta=1.0 if t <= 30 else 3.0,
                                  b_ref=60)

    ctrl = AdaptiveBudget(b_target=target, ema=0.7)
    h_ad = run_amb_adaptive(obj, model_fn, cfg, controller=ctrl, epochs=60,
                            key=jax.random.PRNGKey(0),
                            sample_args=(w_star,), eval_fn=eval_fn,
                            f_star=0.5 * obj.noise_var)
    # fixed-T baseline on the slow phase only (worst case for fixed T)
    h_fix = run_amb(obj, model_fn(60), cfg, epochs=30,
                    key=jax.random.PRNGKey(0), sample_args=(w_star,),
                    eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    fixed_slow_batch = float(h_fix.global_batch.mean())
    adaptive_tail = float(h_ad.global_batch[45:].mean())
    # adaptive recovers to ~target; fixed-T is stuck ~3x under
    assert adaptive_tail > 0.8 * target
    assert fixed_slow_batch < 0.55 * target


def test_adaptive_budget_stationary_matches_lemma6():
    """On a stationary cluster the controller converges to Lemma 6's T."""
    obj, w_star, model, cfg, eval_fn = _setup()
    ctrl = AdaptiveBudget(b_target=600, ema=0.8)
    state = ctrl.init(10.0 * cfg.compute_time)    # start badly mis-tuned
    key = jax.random.PRNGKey(4)
    for t in range(40):
        times = model.per_gradient_times(
            jax.random.fold_in(key, t), cfg.n, cfg.b_max)
        from repro.core.stragglers import amb_batch_sizes
        b = amb_batch_sizes(times, float(state["t_budget"]))
        state = ctrl.update(state, b)
    # Lemma 6's T for this model/batch
    t_lemma6 = amb_budget_from_fmb(model, cfg.n, 600)
    assert abs(float(state["t_budget"]) - t_lemma6) / t_lemma6 < 0.25
