"""Data pipeline, checkpointing, optimizers, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dual_averaging import BetaSchedule
from repro.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.data import LMTokenStream, LinRegStream, LogRegStream
from repro.metrics import MetricsLogger, read_metrics
from repro.optim import make_optimizer


def test_linreg_stream_deterministic_and_iid_across_nodes():
    s = LinRegStream(dim=8, seed=3)
    x1, y1 = s.batch(node=2, epoch=5, size=16)
    x2, y2 = s.batch(node=2, epoch=5, size=16)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    x3, _ = s.batch(node=3, epoch=5, size=16)
    assert not np.allclose(np.asarray(x1), np.asarray(x3))
    # labels consistent with w*
    resid = y1 - x1 @ s.w_star()
    assert float(jnp.std(resid)) < 0.2


def test_logreg_stream_classes():
    s = LogRegStream(dim=16, num_classes=4, seed=1)
    x, y = s.batch(0, 0, 256)
    assert set(np.unique(np.asarray(y))) <= set(range(4))
    assert x.shape == (256, 16)


def test_lm_stream_shapes_and_shift():
    s = LMTokenStream(vocab_size=64, seq_len=12, seed=0)
    b = s.batch(0, 0, 4)
    assert b["tokens"].shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert bool(jnp.all(b["labels"][:, -1] == -1))
    # markov structure: same-block transitions more likely than random
    assert int(b["tokens"].max()) < 64


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.int32(7)}}
    save_checkpoint(tmp_path, 42, tree)
    assert latest_step(tmp_path) == 42
    out = load_checkpoint(tmp_path, 42, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.parametrize("name,kw", [
    ("sgd", dict(lr=0.1)),
    ("sgd", dict(lr=0.1, momentum=0.9)),
    ("adamw", dict(lr=0.05)),
    ("dual_averaging", dict(beta=BetaSchedule(k=1.0, mu=1.0))),
])
def test_optimizers_descend_quadratic(name, kw):
    opt = make_optimizer(name, **kw)
    w_star = {"w": jnp.asarray([2.0, -1.0]), "b": jnp.asarray([0.5])}
    params = jax.tree.map(jnp.zeros_like, w_star)
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(w_star)))

    l0 = float(loss(params))
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state = opt.apply(grads, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_metrics_logger(tmp_path):
    path = tmp_path / "m.jsonl"
    lg = MetricsLogger(path)
    lg.log(0, loss=1.5, tag="x")
    lg.log(1, loss=jnp.float32(0.75))
    lg.close()
    recs = read_metrics(path)
    assert len(recs) == 2 and recs[1]["loss"] == 0.75
