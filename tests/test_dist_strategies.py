"""The repro.dist.consensus strategy layer + the pipelined epoch.

Cross-implementation contracts, in-process on the single real CPU device:
tap-decomposed ring/torus gossip vs the dense ``core.consensus.gossip``
operator, quantized gossip vs ``core.extensions.gossip_quantized``
(including bias/variance behavior), and the staleness-1 pipelined step's
flush equivalence to the sequential gossip step.  The mesh-heavy
(subprocess, forced-device) variants live at the bottom, marked slow.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cns
from repro.core.extensions import gossip_quantized
from repro.dist.consensus import (ExactConsensus, GossipConsensus,
                                  QuantizedGossipConsensus, group_taps,
                                  make_strategy)


# ---------------------------------------------------------------------------
# Tap decomposition
# ---------------------------------------------------------------------------

def test_group_taps_ring_and_torus_reconstruct_p():
    for p, shape in [
        (cns.metropolis_weights(cns.ring_graph(6)), (6,)),
        (cns.metropolis_weights(cns.torus_graph(3, 4)), (3, 4)),
        (cns.metropolis_weights(cns.torus_graph(2, 16)), (2, 16)),
    ]:
        taps = group_taps(p, shape)
        assert taps is not None
        assert not any(taps.offsets[0])          # self tap first
        assert abs(float(taps.weights.sum()) - 1.0) < 1e-6

def test_group_taps_rejects_non_circulant():
    # star graph: hub degree != spoke degree -> P not group-circulant
    p = cns.metropolis_weights(cns.star_graph(6))
    assert group_taps(p, (6,)) is None
    # the paper's ring-plus-chords graph is not vertex transitive either
    p = cns.metropolis_weights(cns.build_graph("paper", 10), lazy=0.3)
    assert group_taps(p, (10,)) is None


# ---------------------------------------------------------------------------
# GossipConsensus == core.consensus.gossip (same P, same rounds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols,rounds", [(2, 2, 3), (2, 3, 7),
                                              (3, 4, 12), (2, 16, 5)])
def test_torus_gossip_matches_core_gossip(rows, cols, rounds):
    """Torus strategy == dense gossip with the torus_graph Metropolis P."""
    n = rows * cols
    msgs = jax.random.normal(jax.random.PRNGKey(n + rounds), (n, 33))
    p = cns.metropolis_weights(cns.torus_graph(rows, cols), lazy=0.5)
    want = cns.gossip(msgs, jnp.asarray(p, jnp.float32), rounds)
    got = GossipConsensus(n, rounds, "torus",
                          torus_shape=(rows, cols)).combine(msgs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_dense_fallback_matches_core_gossip():
    """Non-circulant graphs run the dense operator — same result."""
    g = GossipConsensus(10, 6, "paper", lazy=0.3)
    assert g.taps is None
    msgs = jax.random.normal(jax.random.PRNGKey(3), (10, 21))
    want = cns.gossip(msgs, jnp.asarray(g.p, jnp.float32), 6)
    np.testing.assert_allclose(np.asarray(g.combine(msgs)),
                               np.asarray(want), rtol=1e-6)


def test_exact_strategy_is_global_mean():
    msgs = jax.random.normal(jax.random.PRNGKey(0), (5, 13))
    out = ExactConsensus(5).combine(msgs)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.asarray(msgs.mean(0)),
                                               msgs.shape), rtol=1e-6)


# ---------------------------------------------------------------------------
# QuantizedGossipConsensus == core.extensions.gossip_quantized
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph,shape,bits", [("ring", None, 8),
                                              ("ring", None, 4),
                                              ("torus", (2, 3), 8),
                                              ("torus", (2, 3), 4)])
def test_quantized_strategy_matches_core(graph, shape, bits):
    """Same per-round uniform draws -> the tap-decomposed quantized gossip
    reproduces the dense CHOCO reference within float tolerance.

    The atol covers stochastic-rounding boundary flips: the two
    separately-compiled programs reduce the per-row grid (lo/scale) in
    different orders, so a draw within an ulp of a rounding threshold can
    flip — bounded by one (decayed) delta quantum."""
    n, rounds = 6, 8
    key = jax.random.PRNGKey(11)
    msgs = jax.random.normal(jax.random.fold_in(key, 1), (n, 64)) * 3.0
    q = QuantizedGossipConsensus(n, rounds, bits, graph, torus_shape=shape)
    want = gossip_quantized(msgs, jnp.asarray(q.p, jnp.float32), rounds,
                            bits, key)
    got = q.combine(msgs, key)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-3)


def test_quantized_bias_and_variance_bounds():
    """E_key[quantized gossip] ~ fp32 gossip (unbiased stochastic rounding),
    spread decays with more bits, and the consensus error tracks the core
    implementation's."""
    n, rounds, d = 6, 6, 96
    key = jax.random.PRNGKey(5)
    msgs = jax.random.normal(key, (n, d)) * 4.0
    exact = GossipConsensus(n, rounds, "ring").combine(msgs)

    def runs(bits, reps=24):
        q = QuantizedGossipConsensus(n, rounds, bits, "ring")
        return jnp.stack([q.combine(msgs, jax.random.fold_in(key, i))
                          for i in range(reps)])

    out8, out4 = runs(8), runs(4)
    spread = float(msgs.max() - msgs.min())
    # bias: the empirical mean stays well inside the dynamic range noise
    bias8 = float(jnp.abs(out8.mean(0) - exact).max())
    assert bias8 < 0.02 * spread
    # variance: 4-bit levels are 17x coarser -> strictly noisier than 8-bit
    var8 = float(out8.var(axis=0).mean())
    var4 = float(out4.var(axis=0).mean())
    assert var8 < var4
    # consensus error comparable to the core reference at equal rounds
    q8 = QuantizedGossipConsensus(n, rounds, 8, "ring")
    err_mesh = float(cns.consensus_error(q8.combine(msgs, key)))
    err_core = float(cns.consensus_error(gossip_quantized(
        msgs, jnp.asarray(q8.p, jnp.float32), rounds, 8, key)))
    assert err_mesh < 2.0 * err_core + 1e-3


def test_quantized_wire_bytes_accounting():
    d = 1 << 20
    fp = GossipConsensus(8, 1, "ring")
    q8 = QuantizedGossipConsensus(8, 1, 8, "ring")
    q4 = QuantizedGossipConsensus(8, 1, 4, "ring")
    assert fp.wire_bytes_per_round(d) == 4 * d * 2        # 2 ring neighbors
    assert q8.wire_bytes_per_round(d) < fp.wire_bytes_per_round(d) / 3.9
    assert q4.wire_bytes_per_round(d) < fp.wire_bytes_per_round(d) / 7.9


def test_factory_round_scaling_and_names():
    assert make_strategy("exact", 4).name == "exact"
    assert make_strategy("gossip", 4, rounds=5).rounds == 5
    assert make_strategy("gossip_q8", 4, rounds=5).rounds == 20
    assert make_strategy("gossip_q4", 4, rounds=5).rounds == 40
    with pytest.raises(ValueError):
        make_strategy("psum", 4)


# ---------------------------------------------------------------------------
# Pipelined epoch: flush equivalence (single-device mesh, in process)
# ---------------------------------------------------------------------------

def _tiny_setup():
    from repro.core.dual_averaging import BetaSchedule
    from repro.data import LMTokenStream
    from repro.models import init_params
    from repro.models.common import ArchConfig

    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    beta = BetaSchedule(k=5.0, mu=1.0, scale=10.0)
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, mesh, beta, stream, params


def test_pipelined_step_flush_matches_sequential_trivial_mesh():
    """One pipelined step + flush == one sequential gossip step, exactly:
    the same message settles through the same operator, one step later."""
    from repro.dist import use_sharding
    from repro.dist.amb import AMBConfig, make_gossip_train_step
    from repro.dist.pipeline import make_pipelined_gossip_train_step

    cfg, mesh, beta, stream, params = _tiny_setup()
    amb = AMBConfig(consensus="gossip", gossip_rounds=3, beta=beta)
    with use_sharding(mesh):
        batch = stream.batch(0, 0, 2)
        b = jnp.array([2], jnp.int32)
        init_s, gstep = make_gossip_train_step(cfg, mesh, amb)
        s_seq, m_seq = jax.jit(gstep)(init_s(params), batch, b)
        init_p, pstep, flush = make_pipelined_gossip_train_step(
            cfg, mesh, amb)
        s_pipe, m_pipe = jax.jit(pstep)(init_p(params), batch, b)
        s_pipe = jax.jit(flush)(s_pipe)
    assert float(m_pipe["global_batch"]) == float(m_seq["global_batch"])
    for a, bz in zip(jax.tree.leaves(s_seq["z"]),
                     jax.tree.leaves(s_pipe["z"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bz))


def test_pipelined_first_step_leaves_dual_untouched():
    """Epoch 1 has nothing in flight: the zero pending message's zero
    normaliser must hit the empty-neighborhood guard, not zero the dual."""
    from repro.dist import use_sharding
    from repro.dist.amb import AMBConfig
    from repro.dist.pipeline import make_pipelined_gossip_train_step

    cfg, mesh, beta, stream, params = _tiny_setup()
    amb = AMBConfig(consensus="gossip", gossip_rounds=2, beta=beta)
    with use_sharding(mesh):
        init_p, pstep, _ = make_pipelined_gossip_train_step(cfg, mesh, amb)
        s0 = init_p(params)
        s1, _ = jax.jit(pstep)(s0, stream.batch(0, 0, 2),
                               jnp.array([2], jnp.int32))
    for a, bz in zip(jax.tree.leaves(s0["z"]), jax.tree.leaves(s1["z"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bz))
    assert float(jnp.abs(s1["pending"]).sum()) > 0     # message enqueued


# ---------------------------------------------------------------------------
# Mesh-heavy variants (subprocess, forced host devices) — slow
# ---------------------------------------------------------------------------

from test_dist import run_sub as _run_sub      # the canonical forced-
# device subprocess runner (see tests/test_dist.py)


@pytest.mark.slow
def test_pipelined_flush_equivalence_on_mesh():
    """Flush equivalence + staleness-1 on a real 4x2 mesh, for the ring,
    torus, and quantized strategies."""
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.dist import use_sharding
        from repro.dist.amb import AMBConfig, make_gossip_train_step
        from repro.dist.pipeline import make_pipelined_gossip_train_step
        from repro.data import LMTokenStream, shard_batch
        from repro.models import init_params
        from repro.core.dual_averaging import BetaSchedule

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2-1.5b")
        beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        b = jnp.array([2, 1, 2, 2], jnp.int32)
        for consensus, graph in [("gossip", "ring"), ("gossip", "torus"),
                                 ("gossip_q8", "torus")]:
            amb = AMBConfig(consensus=consensus, gossip_rounds=4,
                            graph=graph, beta=beta)
            with use_sharding(mesh):
                params = init_params(jax.random.PRNGKey(0), cfg)
                batch = shard_batch(stream.batch(0, 0, 8), mesh)
                init_s, gstep = make_gossip_train_step(cfg, mesh, amb)
                s_seq, _ = jax.jit(gstep)(init_s(params), batch, b)
                init_p, pstep, flush = make_pipelined_gossip_train_step(
                    cfg, mesh, amb)
                s_pipe, _ = jax.jit(pstep)(init_p(params), batch, b)
                s_flush = jax.jit(flush)(s_pipe)
                err = max(float(jnp.abs(a - bb).max()) for a, bb in
                          zip(jax.tree.leaves(s_seq["z"]),
                              jax.tree.leaves(s_flush["z"])))
                assert err == 0.0, (consensus, graph, err)
                # staleness-1: a second pipelined step's dual (settles the
                # first message) also equals the sequential first step
                s_pipe2, _ = jax.jit(pstep)(s_pipe, batch, b)
                err2 = max(float(jnp.abs(a - bb).max()) for a, bb in
                           zip(jax.tree.leaves(s_seq["z"]),
                               jax.tree.leaves(s_pipe2["z"])))
                assert err2 == 0.0, (consensus, graph, err2)
                print("OK", consensus, graph)
    """)
    assert out.count("OK") == 3


@pytest.mark.slow
def test_torus_gossip_step_trains_on_mesh():
    """--consensus gossip --graph torus end-to-end on the forced-host
    mesh: the acceptance path, minus the CLI."""
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.dist import use_sharding
        from repro.dist.amb import AMBConfig, make_gossip_train_step
        from repro.dist.consensus import torus_shape_for_mesh
        from repro.data import LMTokenStream, shard_batch
        from repro.models import init_params
        from repro.core.dual_averaging import BetaSchedule

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert torus_shape_for_mesh(mesh) == (2, 2)
        cfg = smoke_config("qwen2-1.5b")
        beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
        amb = AMBConfig(consensus="gossip", gossip_rounds=40,
                        graph="torus", beta=beta)
        init_state, step = make_gossip_train_step(cfg, mesh, amb)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        with use_sharding(mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            state = init_state(params)
            b = jnp.array([2, 1, 2, 0], jnp.int32)
            batch = shard_batch(stream.batch(0, 0, 8), mesh)
            state, m = jax.jit(step)(state, batch, b)
        assert float(m["global_batch"]) == 5.0
        assert jnp.isfinite(m["loss"])
        # 40 rounds over the 2x2 torus -> near-consensus across pods
        spread = max(float(jnp.std(z.astype(jnp.float32), axis=0).max())
                     for z in jax.tree.leaves(state["z"]))
        print("spread", spread)
        assert spread < 1e-5
    """)
    assert "spread" in out
