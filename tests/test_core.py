"""Dual averaging, straggler models, objectives (paper §3-§5 mechanics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BetaSchedule, Deterministic, InducedGroups,
                        PauseModel, ShiftedExponential, amb_batch_sizes,
                        amb_budget_from_fmb, fmb_finish_times, prox_step)
from repro.core.objectives import LinearRegression, LogisticRegression
from repro.core.regret import (shifted_exp_asymptotic_ratio, shifted_exp_ratio,
                               theorem7_ratio)


# ---------------------------------------------------------------------------
# dual averaging
# ---------------------------------------------------------------------------

def test_prox_matches_numeric_argmin():
    """prox = argmin <w,z> + beta ||w||^2 (checked by gradient stationarity)."""
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (32,))
    beta = jnp.float32(2.5)
    w = prox_step(z, beta)
    # stationarity: z + 2 beta w = 0
    np.testing.assert_allclose(np.asarray(z + 2 * beta * w), 0.0, atol=1e-6)


def test_prox_ball_projection():
    z = jnp.full((8,), -10.0)
    w = prox_step(z, jnp.float32(0.5), radius=1.0)
    assert abs(float(jnp.linalg.norm(w)) - 1.0) < 1e-5


def test_beta_schedule_nondecreasing():
    beta = BetaSchedule(k=2.0, mu=10.0)
    ts = jnp.arange(1, 100)
    vals = beta(ts)
    assert bool(jnp.all(jnp.diff(vals) >= 0))
    assert float(vals[0]) > 0


def test_dual_averaging_converges_on_quadratic():
    """Centralised dual averaging on F(w)=0.5||w - w*||^2 with exact grads."""
    w_star = jnp.asarray([1.0, -2.0, 3.0])
    beta = BetaSchedule(k=1.0, mu=1.0)
    z = jnp.zeros(3)
    w = jnp.zeros(3)
    for t in range(1, 2000):
        g = w - w_star
        z = z + g
        w = prox_step(z, beta(t + 1))
    # dual averaging converges to a minimiser-adjacent point at O(1/sqrt(t))
    assert float(jnp.linalg.norm(w - w_star)) < 0.2


# ---------------------------------------------------------------------------
# straggler models
# ---------------------------------------------------------------------------

MODELS = [Deterministic(grad_time=0.01, b_ref=100),
          ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=600),
          InducedGroups(),
          PauseModel(group_sizes=(2, 2, 2, 2, 2))]


@pytest.mark.parametrize("model", MODELS, ids=lambda m: type(m).__name__)
def test_per_gradient_times_shape_positive(model):
    n = sum(getattr(model, "group_sizes", [4])) if hasattr(
        model, "group_sizes") else 4
    t = model.per_gradient_times(jax.random.PRNGKey(0), n, 50)
    assert t.shape == (n, 50)
    assert bool(jnp.all(t > 0))


def test_amb_batch_monotone_in_budget():
    model = ShiftedExponential()
    times = model.per_gradient_times(jax.random.PRNGKey(0), 8, 500)
    b1 = amb_batch_sizes(times, 0.5)
    b2 = amb_batch_sizes(times, 1.5)
    assert bool(jnp.all(b2 >= b1))
    assert bool(jnp.all(b2 <= 500))


def test_fmb_finish_monotone_in_batch():
    model = ShiftedExponential()
    times = model.per_gradient_times(jax.random.PRNGKey(1), 8, 500)
    f1 = fmb_finish_times(times, 10)
    f2 = fmb_finish_times(times, 100)
    assert bool(jnp.all(f2 > f1))


def test_lemma6_expected_batch_at_least_fmb():
    """E[b_AMB] >= b with T = (1 + n/b) mu (paper Lemma 6), empirically."""
    n, b_global = 10, 600
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=b_global // n)
    t_budget = amb_budget_from_fmb(model, n, b_global)
    totals = []
    for s in range(200):
        times = model.per_gradient_times(jax.random.PRNGKey(s), n, 4 * b_global)
        totals.append(float(amb_batch_sizes(times, t_budget).sum()))
    assert np.mean(totals) >= b_global * 0.98   # >= up to floor() effects


def test_theorem7_wall_clock_bound():
    """S_F <= (1 + sigma/mu sqrt(n-1)) S_A, empirically for shifted exp."""
    n, b_per_node = 10, 60
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=b_per_node)
    t_budget = amb_budget_from_fmb(model, n, n * b_per_node)
    fmb_tot, epochs = 0.0, 300
    for s in range(epochs):
        times = model.per_gradient_times(jax.random.PRNGKey(s), n, 4 * b_per_node)
        fmb_tot += float(fmb_finish_times(times, b_per_node).max())
    s_f = fmb_tot
    s_a = epochs * t_budget
    bound = theorem7_ratio(model.mean_batch_time(), model.std_batch_time(), n)
    assert s_f <= bound * s_a * 1.02
    assert s_f > s_a          # and stragglers really do cost FMB wall time


def test_shifted_exp_ratios():
    r = shifted_exp_ratio(lam=2 / 3, zeta=1.0, n=10, b=600)
    assert r > 1.0
    asym = shifted_exp_asymptotic_ratio(lam=2 / 3, zeta=1.0, n=10)
    assert abs(asym - np.log(10) / (1 + 2 / 3)) < 1e-9


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100))
def test_linreg_masked_sums_match_grad(seed):
    obj = LinearRegression(dim=6)
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (6,))
    w_star = jax.random.normal(jax.random.fold_in(key, 1), (6,))
    batch = obj.sample(jax.random.fold_in(key, 2), (9,), w_star)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (9,)) > 0.4
            ).astype(jnp.float32)
    gsum, lsum = obj.masked_sums(w, batch, mask)
    # against autodiff of the masked *sum* loss
    def sum_loss(w):
        x, y = batch
        r = (x @ w - y)
        return 0.5 * jnp.sum(mask * r * r)
    np.testing.assert_allclose(np.asarray(gsum),
                               np.asarray(jax.grad(sum_loss)(w)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(lsum), float(sum_loss(w)), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100))
def test_logreg_masked_sums_match_autodiff(seed):
    obj = LogisticRegression(dim=5, num_classes=3)
    key = jax.random.PRNGKey(seed)
    w = 0.1 * jax.random.normal(key, (obj.param_dim,))
    means = obj.make_class_means(jax.random.fold_in(key, 1))
    batch = obj.sample(jax.random.fold_in(key, 2), (7,), means)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (7,)) > 0.3
            ).astype(jnp.float32)
    gsum, lsum = obj.masked_sums(w, batch, mask)

    def sum_loss(w):
        x, y = batch
        logits = obj._logits(w, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.sum(mask * jnp.take_along_axis(
            logp, y[:, None], axis=-1)[:, 0])

    np.testing.assert_allclose(np.asarray(gsum),
                               np.asarray(jax.grad(sum_loss)(w)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(lsum), float(sum_loss(w)), rtol=1e-5)


def test_calibrated_budget_hits_target_heterogeneous():
    """amb_budget_calibrated: E[b(T)] ~= b_global for group-heterogeneous
    clusters where the Lemma-6 closed form (Assumption 1: identical T_i)
    overshoots."""
    from repro.core.stragglers import (InducedGroups, amb_budget_calibrated,
                                       amb_budget_from_fmb)
    n, b_global = 10, 1000
    model = InducedGroups(group_sizes=(5, 2, 3), zetas=(9.0, 18.0, 27.0),
                          lams=(1.0, 1.0, 1.0), b_ref=100)
    t_cal = amb_budget_calibrated(model, n, b_global,
                                  key=jax.random.PRNGKey(5))
    t_l6 = amb_budget_from_fmb(model, n, b_global)
    assert t_cal < t_l6          # closed form overshoots on heterogeneity
    totals = []
    for s in range(100):
        times = model.per_gradient_times(
            jax.random.PRNGKey(1000 + s), n, 4 * b_global // n)
        totals.append(float(amb_batch_sizes(times, t_cal).sum()))
    assert abs(np.mean(totals) - b_global) / b_global < 0.1
