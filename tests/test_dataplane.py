"""The data plane (repro.data.loader) + the step-critical-path contracts.

Covers the sharded single-put (``put_batch`` and its deprecated
``shard_batch`` alias), the per-worker stream shards (worker i draws
stream node i — the pre-loader drivers fed every worker node 0), the
background :class:`~repro.data.Prefetcher` (ordering, backpressure,
error propagation, shutdown), TrainState donation through every epoch
driver (the pre-step state's buffers must actually be freed, with no
duplicated live buffers), the kernel router (compiled-Pallas-on-TPU /
jnp-ref-on-CPU decision, env + programmatic overrides), and — slow
marked — the prefetch-overlap win against an artificially costed source.
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (CostedSource, InputSource, LMTokenStream,
                        Prefetcher, StreamSource, SyntheticSource,
                        make_source, put_batch, shard_batch)
from repro.kernels import router

from test_api import _tiny_session
from repro.api import ConsensusSpec


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# put_batch / shard_batch
# ---------------------------------------------------------------------------

def test_put_batch_places_leading_dim_on_data_axis():
    mesh = _mesh11()
    batch = {"tokens": np.arange(32, dtype=np.int32).reshape(4, 8),
             "labels": np.arange(32, dtype=np.int32).reshape(4, 8)}
    dev = put_batch(batch, mesh)
    for leaf in jax.tree.leaves(dev):
        assert isinstance(leaf.sharding, jax.sharding.NamedSharding)
        assert leaf.sharding.spec[0] == ("data",)
    np.testing.assert_array_equal(np.asarray(dev["tokens"]),
                                  batch["tokens"])


def test_put_batch_is_idempotent_no_copy():
    """An already-committed batch passes through without a new buffer —
    what lets session.step call put_batch unconditionally on prefetched
    (already device-resident) batches."""
    mesh = _mesh11()
    batch = {"tokens": np.zeros((4, 8), np.int32)}
    once = put_batch(batch, mesh)
    twice = put_batch(once, mesh)
    assert twice["tokens"] is once["tokens"]


def test_shard_batch_is_a_put_batch_alias():
    mesh = _mesh11()
    batch = {"x": np.ones((2, 4), np.float32)}
    a = shard_batch(batch, mesh)
    b = put_batch(batch, mesh)
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    assert a["x"].sharding == b["x"].sharding


# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------

def test_stream_source_draws_distinct_per_worker_shards():
    """Worker i's block must come from stream node i: distinct i.i.d.
    shards per worker (the old drivers fed node 0 to everyone, so the
    whole fleet trained on identical data)."""
    stream = LMTokenStream(vocab_size=97, seq_len=8, seed=3)
    src = StreamSource(stream, n_workers=4, per_worker=2)
    got = src.batch(5)
    assert jax.tree.leaves(got)[0].shape[0] == src.global_batch == 8
    blocks = [jax.tree.map(lambda x: np.asarray(x)[2 * i:2 * i + 2], got)
              for i in range(4)]
    for i, blk in enumerate(blocks):
        want = stream.batch(i, 5, 2)        # eager reference draw
        np.testing.assert_array_equal(blk["tokens"],
                                      np.asarray(want["tokens"]))
    # and the shards genuinely differ across workers
    assert not np.array_equal(blocks[0]["tokens"], blocks[1]["tokens"])


def test_stream_source_deterministic_in_epoch():
    src = StreamSource(LMTokenStream(vocab_size=31, seq_len=4, seed=0),
                       n_workers=2, per_worker=3)
    a = src.batch(7)
    b = src.batch(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = src.batch(8)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_synthetic_source_lands_on_device_presharded():
    mesh = _mesh11()
    src = SyntheticSource(vocab_size=64, seq_len=8, n_workers=1,
                          per_worker=4, mesh=mesh)
    b = src.batch(0)
    assert b["tokens"].shape == (4, 8)
    assert isinstance(b["tokens"].sharding, jax.sharding.NamedSharding)
    # put_batch on it is the no-copy identity (sharding already matches)
    assert put_batch(b, mesh)["tokens"] is b["tokens"]
    # labels are the next-token shift with a -1 tail
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])
    assert (labels[:, -1] == -1).all()


def test_make_source_registry():
    mesh = _mesh11()
    s1 = make_source("lm", n_workers=2, per_worker=2, vocab_size=17,
                     seq_len=4)
    assert isinstance(s1, StreamSource)
    assert s1.global_batch == 4
    s2 = make_source("synthetic", n_workers=1, per_worker=2, vocab_size=17,
                     seq_len=4, mesh=mesh)
    assert isinstance(s2, SyntheticSource)
    with pytest.raises(KeyError):
        make_source("nope", n_workers=1, per_worker=1)


# ---------------------------------------------------------------------------
# Prefetcher
# ---------------------------------------------------------------------------

class _CountingSource(InputSource):
    n_workers, per_worker = 1, 1

    def __init__(self):
        self.built = []

    def batch(self, epoch):
        self.built.append(epoch)
        return {"e": np.asarray([epoch])}


def test_prefetcher_yields_epochs_in_order_and_stops():
    src = _CountingSource()
    pf = Prefetcher(src, _mesh11(), steps=5, start_epoch=3,
                    put=lambda b: b)
    got = [int(item["e"][0]) for item in pf]
    assert got == [3, 4, 5, 6, 7]
    pf.close()
    pf.close()                              # idempotent


def test_prefetcher_backpressure_bounds_lead():
    """The bounded queue is the backpressure: the thread never builds
    more than depth + 1 epochs ahead of the consumer (depth parked in
    the queue, one in the blocked put)."""
    src = _CountingSource()
    depth = 2
    pf = Prefetcher(src, _mesh11(), steps=10, depth=depth,
                    put=lambda b: b)
    consumed = 0
    max_lead = 0
    for item in pf:
        consumed += 1
        time.sleep(0.02)                    # slow consumer
        max_lead = max(max_lead, len(src.built) - consumed)
    pf.close()
    assert consumed == 10
    assert max_lead <= depth + 1, max_lead


def test_prefetcher_propagates_source_errors():
    class Boom(InputSource):
        n_workers, per_worker = 1, 1

        def batch(self, epoch):
            if epoch == 2:
                raise RuntimeError("bad shard")
            return {"e": np.asarray([epoch])}

    pf = Prefetcher(Boom(), _mesh11(), steps=5, put=lambda b: b)
    assert int(next(pf)["e"][0]) == 0
    assert int(next(pf)["e"][0]) == 1
    with pytest.raises(RuntimeError, match="bad shard"):
        next(pf)
    pf.close()


def test_prefetcher_close_unblocks_producer():
    src = _CountingSource()
    pf = Prefetcher(src, _mesh11(), steps=100, depth=1, put=lambda b: b)
    next(pf)
    pf.close()                              # thread mid-put must exit
    assert not pf._thread.is_alive()


def test_prefetcher_error_sentinel_honors_close_on_full_queue():
    """A source error with the queue already full must not strand the
    producer: the error sentinel's put goes through the same
    stop-polling loop as batches, so close() still reaps the thread
    even when the consumer never drains the error."""
    class BoomAfterFill(InputSource):
        n_workers, per_worker = 1, 1

        def batch(self, epoch):
            if epoch >= 1:                  # epoch 0 fills the depth-1 queue
                raise RuntimeError("late boom")
            return {"e": np.asarray([epoch])}

    pf = Prefetcher(BoomAfterFill(), _mesh11(), steps=5, depth=1,
                    put=lambda b: b)
    # wait for the producer to park epoch 0 and hit the error while the
    # queue is full — its sentinel put is now blocked on the consumer
    deadline = time.monotonic() + 5.0
    while pf._q.empty() and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)
    pf.close()                              # never consumed anything
    pf._thread.join(timeout=5.0)
    assert not pf._thread.is_alive()


def test_prefetcher_puts_batches_on_device():
    mesh = _mesh11()
    src = _CountingSource()
    pf = Prefetcher(src, mesh, steps=2)     # default put = put_batch
    item = next(pf)
    assert isinstance(item["e"].sharding, jax.sharding.NamedSharding)
    pf.close()


# ---------------------------------------------------------------------------
# Session integration: run(), donation, restore data order
# ---------------------------------------------------------------------------

def test_session_run_matches_manual_step_loop():
    """run() through the prefetched plane reproduces the manual
    step-by-step loop exactly (token draws are bit-identical)."""
    sA, _ = _tiny_session()
    sB, _ = _tiny_session()
    losses_run = []
    sA.run(3, on_step=lambda s, m: losses_run.append(m["loss"]))
    src = sB.batch_source()
    losses_manual = [sB.step(src.batch(e))["loss"] for e in range(3)]
    assert losses_run == losses_manual
    assert sA.steps_done == sB.steps_done == 3


def test_session_run_zero_steps_is_noop():
    s, _ = _tiny_session()
    assert s.run(0) is None
    assert s.steps_done == 0


def test_session_run_sync_path_matches_prefetched():
    sA, _ = _tiny_session()
    sB, _ = _tiny_session()
    mA = sA.run(2, prefetch=2)
    mB = sB.run(2, prefetch=0)
    assert mA["loss"] == mB["loss"]


def test_session_run_surfaces_source_error_and_stays_usable():
    """A source raising mid-run must surface from session.run itself —
    not hang, not vanish into the prefetch thread — and leave the
    session flushable and steppable, with the producer thread reaped."""
    import threading

    s, _ = _tiny_session(ConsensusSpec(consensus="gossip", graph="ring",
                                       async_epochs=True, staleness=2))
    inner = s.batch_source()

    class Flaky(InputSource):
        n_workers = inner.n_workers
        per_worker = inner.per_worker

        def batch(self, epoch):
            if epoch == 2:
                raise RuntimeError("shard fetch failed")
            return inner.batch(epoch)

    threads_before = threading.active_count()
    with pytest.raises(RuntimeError, match="shard fetch failed"):
        s.run(5, source=Flaky())
    # run's finally closed the prefetcher: no leaked producer thread
    deadline = time.monotonic() + 5.0
    while threading.active_count() > threads_before \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= threads_before
    assert s.steps_done == 2                # the epochs that completed
    s.flush()                               # drains in-flight consensus
    m = s.step(inner.batch(2))              # and the session still steps
    assert np.isfinite(m["loss"])
    s.close()


@pytest.mark.parametrize("consensus", [
    ConsensusSpec(),
    ConsensusSpec(consensus="gossip", graph="ring"),
    ConsensusSpec(consensus="gossip", graph="ring", pipeline=True),
    ConsensusSpec(consensus="gossip", graph="ring", async_epochs=True,
                  staleness=2),
], ids=["exact", "gossip", "pipelined", "async_D2"])
def test_donated_state_is_freed_every_protocol(consensus):
    """donate_argnums must hold through every epoch driver: after a
    step, every leaf of the pre-step TrainState is deleted (its buffer
    was reused in place, not shadowed by a second allocation), and the
    process-wide live-buffer count stays flat step over step."""
    s, _ = _tiny_session(consensus)
    src = s.batch_source()
    s.step(src.batch(0))                    # compile outside the count
    old = s.state
    live_before = len(jax.live_arrays())
    s.step(src.batch(1))
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))
    s.step(src.batch(2))
    assert len(jax.live_arrays()) <= live_before
    # flush donates too; the session stays usable afterwards
    pre_flush = s.state
    s.flush()
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(pre_flush))
    _ = s.params


def test_donation_survives_staleness_retune():
    """_apply_staleness reassembles the state from pieces of the old
    one; the rebuilt state must still be donation-clean (no leaf object
    appearing twice)."""
    s, _ = _tiny_session(ConsensusSpec(consensus="gossip", graph="ring",
                                       async_epochs=True, staleness=2))
    src = s.batch_source()
    s.step(src.batch(0))
    s._apply_staleness(3)
    old = s.state
    s.step(src.batch(1))                    # would raise on double-donate
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))


def test_restored_session_continues_data_order(tmp_path):
    """A save/restore must not rewind or skip stream epochs: restored
    run(n) consumes exactly the epochs an uninterrupted run would."""
    sA, _ = _tiny_session()
    sA.run(4)
    ref_loss = sA.run(1)["loss"]            # epoch 4 in one long run

    sB, _ = _tiny_session()
    sB.run(4)
    sB.save(tmp_path / "ck")
    from repro.api import AMBSession
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    restored = AMBSession.restore(tmp_path / "ck", mesh=_mesh11(), cfg=cfg)
    assert restored.steps_done == 4
    assert restored.run(1)["loss"] == ref_loss


# ---------------------------------------------------------------------------
# Kernel router
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _reset_router():
    yield
    router.set_mode(None)
    os.environ.pop("REPRO_KERNELS", None)


def test_router_auto_routes_ref_on_cpu():
    if jax.default_backend() not in ("tpu", "gpu"):
        assert router.resolve() == "ref"
    else:
        assert router.resolve() == "pallas"
    # the hot path must never silently run the grid-emulation oracle
    assert router.resolve() != "pallas_interpret"


def test_router_env_and_set_mode_overrides():
    os.environ["REPRO_KERNELS"] = "pallas_interpret"
    assert router.mode() == "pallas_interpret"
    assert router.resolve() == "pallas_interpret"
    router.set_mode("ref")                  # programmatic beats env
    assert router.resolve() == "ref"
    router.set_mode(None)                   # back to env
    assert router.resolve() == "pallas_interpret"
    os.environ["REPRO_KERNELS"] = "bogus"
    with pytest.raises(ValueError, match="REPRO_KERNELS"):
        router.mode()


def test_router_force_and_validation():
    assert router.resolve(force="pallas_interpret") == "pallas_interpret"
    assert router.resolve(force="ref") == "ref"
    with pytest.raises(ValueError):
        router.resolve(force="auto")        # force must be concrete
    with pytest.raises(ValueError):
        router.set_mode("bogus")


def test_ops_dispatch_follows_router():
    """ops.gossip_combine under set_mode('ref') equals the forced
    interpret oracle — same math, routed implementation."""
    from repro.kernels import ops
    msgs = jax.random.normal(jax.random.PRNGKey(0), (3, 256), jnp.float32)
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    router.set_mode("ref")
    got = ops.gossip_combine(msgs, w)
    want = ops.gossip_combine(msgs, w, force="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


def test_trainspec_kernels_flag_pins_router():
    s, _ = _tiny_session()                  # default: auto, leaves router
    from repro.api import TrainSpec
    import argparse
    ap = argparse.ArgumentParser()
    TrainSpec.add_cli_args(ap)
    args = ap.parse_args(["--kernels", "ref"])
    assert TrainSpec.from_args(args).kernels == "ref"
    with pytest.raises(SystemExit):
        ap.parse_args(["--kernels", "bogus"])


# ---------------------------------------------------------------------------
# Overlap (slow): the prefetched plane must beat the sync loop
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefetch_overlap_beats_sync_with_costed_source():
    """With an I/O-bound host cost ~ the step time, the prefetched data
    plane must hide the host path behind the device step.  The margin
    asserted (1.15x) is deliberately below the benchmarked ~1.4x to
    keep the test robust on loaded CI hosts.

    Needs a step large enough to dominate the queue/thread overhead
    (the 1x1 smoke step is ~1 ms — nothing to hide a cost behind), so
    this builds a wider model than ``_tiny_session``.
    """
    from repro.api import AMBSession, ClockSpec, TrainSpec
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t2", family="dense", num_layers=2, d_model=128,
                     num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
                     vocab_size=256, q_chunk=32, kv_chunk=32,
                     mxu_f32_accum=False)
    s = AMBSession(TrainSpec(batch_per_worker=8, seq_len=64),
                   ClockSpec(kind="simulated"), ConsensusSpec(),
                   mesh=_mesh11(), cfg=cfg)
    src = s.batch_source()
    s.run(2, src)                           # compile + warm
    t0 = time.perf_counter()
    s.run(4, src, prefetch=0)
    step_s = (time.perf_counter() - t0) / 4

    costed = CostedSource(src, step_s)
    t0 = time.perf_counter()
    s.run(6, costed, prefetch=0)
    t_sync = (time.perf_counter() - t0) / 6
    t0 = time.perf_counter()
    s.run(6, costed, prefetch=2)
    t_pre = (time.perf_counter() - t0) / 6
    assert t_sync / t_pre > 1.15, (t_sync, t_pre)
