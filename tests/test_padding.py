"""Sharding-alignment paddings from §Perf: numerically exact by design."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import ssm
from repro.models.model import init_params, lm_loss, logits_fn, forward


def test_rwkv6_head_padding_exact_forward_and_decode():
    """head_pad_to: padded channels carry r=k=v=0 -> identical outputs."""
    cfg = smoke_config("rwkv6-3b")
    cfgp = dataclasses.replace(cfg, head_pad_to=3)   # 2 heads -> 3
    p = ssm.rwkv6_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y0 = ssm.rwkv6_forward(p, x, cfg)
    y1 = ssm.rwkv6_forward(p, x, cfgp)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32), atol=1e-5)

    st0 = ssm.rwkv6_init_state(cfg, 2)
    st1 = ssm.rwkv6_init_state(cfgp, 2)
    assert st1.s.shape[1] == 3 and st0.s.shape[1] == 2
    d0, n0 = ssm.rwkv6_decode(p, x[:, :1], st0, cfg)
    d1, n1 = ssm.rwkv6_decode(p, x[:, :1], st1, cfgp)
    np.testing.assert_allclose(np.asarray(d0, np.float32),
                               np.asarray(d1, np.float32), atol=1e-5)
    # padded state rows stay identically zero
    np.testing.assert_array_equal(np.asarray(n1.s[:, 2:]), 0.0)


def test_rwkv6_padded_state_stays_zero_over_steps():
    cfg = dataclasses.replace(smoke_config("rwkv6-3b"), head_pad_to=4)
    p = ssm.rwkv6_params(jax.random.PRNGKey(2), cfg)
    st = ssm.rwkv6_init_state(cfg, 1)
    key = jax.random.PRNGKey(3)
    for i in range(5):
        x = jax.random.normal(jax.random.fold_in(key, i),
                              (1, 1, cfg.d_model), jnp.bfloat16)
        _, st = ssm.rwkv6_decode(p, x, st, cfg)
    np.testing.assert_array_equal(np.asarray(st.s[:, 2:]), 0.0)


def test_vocab_padding_exact_loss_and_logits():
    """vocab_pad_to: params padded, logits sliced -> same loss/logit values
    (same rng => the first V columns of the padded init are identical)."""
    cfg = smoke_config("qwen2-1.5b")
    cfgp = dataclasses.replace(cfg, vocab_pad_to=cfg.vocab_size + 64)
    assert cfgp.padded_vocab == cfg.vocab_size + 64

    params = init_params(jax.random.PRNGKey(0), cfgp)
    assert params["embed"].shape[0] == cfgp.padded_vocab
    assert params["unembed"].shape[1] == cfgp.padded_vocab

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jnp.concatenate(
                 [toks[:, 1:], jnp.full((2, 1), -1, toks.dtype)], axis=1)}
    hidden, _ = forward(params, cfgp, batch)
    logits = logits_fn(params, cfgp, hidden)
    assert logits.shape[-1] == cfg.vocab_size          # sliced back
    loss, m = lm_loss(params, cfgp, batch)
    assert bool(jnp.isfinite(loss))

    # gradient flows only into real vocab rows of unembed
    g = jax.grad(lambda p_: lm_loss(p_, cfgp, batch)[0])(params)
    np.testing.assert_array_equal(
        np.asarray(g["unembed"][:, cfg.vocab_size:], np.float32), 0.0)
