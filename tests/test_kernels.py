"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.dual_update import dual_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gossip_combine import (gossip_combine_pallas,
                                          quantized_combine_pallas,
                                          stochastic_quantize_pallas)
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


# ---------------------------------------------------------------------------
# dual_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (128,), (1000, 37), (3, 5, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dual_update_sweep(shape, dtype):
    k = jax.random.PRNGKey(0)
    z = jax.random.normal(k, shape, jnp.float32)
    w0 = jax.random.normal(jax.random.fold_in(k, 1), shape, dtype)
    beta = jnp.float32(1.7)
    got = dual_update_pallas(z, w0, beta, interpret=True, block=2048)
    want = ref.dual_update_ref(z, w0, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dual_update_op_with_radius():
    z = jnp.full((16,), 100.0)
    w0 = jnp.zeros((16,))
    w = ops.dual_update(z, w0, jnp.float32(1.0), radius=1.0, force="ref")
    assert abs(float(jnp.linalg.norm(w)) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# gossip_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n", [(2, 100), (3, 4096), (5, 999)])
def test_gossip_combine_sweep(k, n):
    key = jax.random.PRNGKey(1)
    msgs = jax.random.normal(key, (k, n))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (k,)))
    got = gossip_combine_pallas(msgs, w, interpret=True, block_rows=16)
    want = ref.gossip_combine_ref(msgs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# quantized-gossip kernels (send: stochastic quantize; receive: combine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,bits", [(4, 300, 8), (3, 1024, 4), (8, 77, 8)])
def test_stochastic_quantize_sweep(n, d, bits):
    key = jax.random.PRNGKey(2)
    m = jax.random.normal(key, (n, d)) * 2.0
    h = jax.random.normal(jax.random.fold_in(key, 1), (n, d)) * 0.3
    rnd = jax.random.uniform(jax.random.fold_in(key, 2), (n, d))
    diff = m - h
    lo = diff.min(-1, keepdims=True)
    scale = jnp.maximum(diff.max(-1, keepdims=True) - lo, 1e-12) \
        / (2 ** bits - 1)
    lvl, hnew = stochastic_quantize_pallas(m, h, rnd, lo, scale,
                                           interpret=True, block_rows=4)
    lvl_r, hnew_r = ref.stochastic_quantize_ref(m, h, rnd, lo, scale)
    np.testing.assert_array_equal(np.asarray(lvl), np.asarray(lvl_r))
    np.testing.assert_allclose(np.asarray(hnew), np.asarray(hnew_r),
                               rtol=1e-5, atol=1e-5)
    assert int(lvl.max()) <= 2 ** bits - 1


@pytest.mark.parametrize("n,d,km1", [(4, 300, 2), (6, 129, 4)])
def test_quantized_combine_sweep(n, d, km1):
    key = jax.random.PRNGKey(3)
    m = jax.random.normal(key, (n, d))
    hnbr = jax.random.normal(jax.random.fold_in(key, 1), (km1, n, d))
    lvl = jax.random.randint(jax.random.fold_in(key, 2), (km1, n, d),
                             0, 256).astype(jnp.uint8)
    lo = jax.random.normal(jax.random.fold_in(key, 3), (km1, n, 1))
    scale = jax.random.uniform(jax.random.fold_in(key, 4),
                               (km1, n, 1)) * 0.01
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 5),
                                         (km1 + 1,)))
    got_o, got_h = quantized_combine_pallas(m, hnbr, lvl, lo, scale, w,
                                            interpret=True, block_rows=8)
    want_o, want_h = ref.quantized_combine_ref(m, hnbr, lvl, lo, scale, w)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # (B, H, KV, Sq, Skv, hd, causal, window)
    (1, 4, 4, 64, 64, 32, True, 0),        # MHA causal
    (2, 4, 2, 100, 100, 64, True, 0),      # GQA, ragged seq
    (1, 8, 2, 128, 128, 64, True, 32),     # sliding window
    (1, 2, 2, 64, 128, 32, False, 0),      # cross attention (no causal)
    (1, 4, 1, 257, 257, 64, True, 64),     # MQA, odd seq
]


@pytest.mark.parametrize("b,h,kv,sq,skv,hd,causal,window", CASES)
def test_flash_attention_sweep(b, h, kv, sq, skv, hd, causal, window):
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, h, sq, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, skv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, skv, hd))
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 4, 64, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 64),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 64),
                          jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, window=0,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_flash_attention_q_offset_decode_semantics():
    """q_offset positions queries mid-cache (decode-style masking)."""
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 2, 8, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 32))
    got = flash_attention_pallas(q, k, v, causal=True, window=0, q_offset=40,
                                 block_q=8, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=0,
                                   q_offset=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,hd,chunk", [(2, 64, 32, 16), (4, 100, 64, 16),
                                           (1, 17, 64, 8), (3, 256, 64, 32)])
def test_rwkv6_scan_sweep(bh, s, hd, chunk):
    key = jax.random.PRNGKey(5)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (bh, s, hd))
    r, k, v = mk(0), mk(1), mk(2)
    decay = 0.2 + 0.8 * jax.random.uniform(jax.random.fold_in(key, 3),
                                           (bh, s, hd))
    u = jax.random.normal(jax.random.fold_in(key, 4), (bh, hd))
    got = rwkv6_scan_pallas(r, k, v, decay, u, chunk=chunk, interpret=True)
    want = ref.rwkv6_chunk_ref(
        r.reshape(1, bh, s, hd), k.reshape(1, bh, s, hd),
        v.reshape(1, bh, s, hd), decay.reshape(1, bh, s, hd),
        u).reshape(bh, s, hd)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_rwkv6_ops_matches_ref_property(seed):
    key = jax.random.PRNGKey(seed)
    bh, s, hd = 2, 37, 64
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (bh, s, hd))
    decay = 0.5 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 9),
                                           (bh, s, hd))
    u = jax.random.normal(jax.random.fold_in(key, 4), (bh, hd))
    got = ops.rwkv6_scan(mk(0), mk(1), mk(2), decay, u,
                         force="pallas_interpret")
    want = ops.rwkv6_scan(mk(0), mk(1), mk(2), decay, u, force="ref")
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=3e-5)
