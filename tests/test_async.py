"""AsyncProtocol: bounded-staleness delayed-gradient epochs + restore.

Fast in-process tests cover the spec/CLI surface of ``--async
--staleness``, the dispatch rules, the AMB-DG reference simulator's
staleness-D convergence on the quadratic objective (and its
``max(T, T_c/D)`` wall-clock model), and the session restore round trip
on a trivial mesh.  The slow subprocess suite is the correctness anchor:
``AsyncProtocol(staleness=1)`` flush must be **bit-identical** to
``PipelinedProtocol`` on 8 forced host devices, and a mid-flight
save/restore must resume the training trajectory exactly.
"""
import argparse

import jax
import numpy as np
import pytest

from repro.api import ConsensusSpec, build_protocol
from repro.core import BetaSchedule, EngineConfig, ShiftedExponential
from repro.core.extensions import run_amb_delayed, run_amb_pipelined
from repro.core.objectives import LinearRegression
from repro.core.stragglers import amb_budget_from_fmb
from repro.dist.amb import AMBConfig

from test_dist import run_sub      # canonical forced-device subprocess


# ---------------------------------------------------------------------------
# Spec + dispatch surface
# ---------------------------------------------------------------------------

def test_async_spec_roundtrips():
    spec = ConsensusSpec(consensus="gossip", async_epochs=True, staleness=3)
    assert ConsensusSpec.from_json(spec.to_json()) == spec

    ap = argparse.ArgumentParser()
    ConsensusSpec.add_cli_args(ap)
    args = ap.parse_args(["--consensus", "gossip", "--async",
                          "--staleness", "3"])
    assert ConsensusSpec.from_args(args) == spec
    # default stays sequential
    assert not ConsensusSpec.from_args(ap.parse_args([])).async_epochs


def test_build_protocol_async_dispatch_rules():
    from repro.optim import AdamW
    amb = AMBConfig(consensus="gossip")
    with pytest.raises(ValueError):       # drivers are mutually exclusive
        build_protocol(None, None, amb, pipeline=True, async_epochs=True)
    with pytest.raises(ValueError):       # staleness is async-only
        build_protocol(None, None, amb, staleness=3)
    with pytest.raises(ValueError):       # async is dual-averaging only
        build_protocol(None, None, AMBConfig(), optimizer=AdamW(),
                       async_epochs=True)
    with pytest.raises(ValueError):       # queue needs >= 1 slot
        from repro.dist.async_epochs import make_async_gossip_train_step
        make_async_gossip_train_step(None, jax.make_mesh((1,), ("data",)),
                                     AMBConfig(), staleness=0)


def test_session_rejects_non_dual_averaging_async():
    from repro.api import AMBSession, ClockSpec, TrainSpec
    with pytest.raises(ValueError):
        AMBSession(TrainSpec(optimizer="adamw"),
                   ClockSpec(kind="simulated"),
                   ConsensusSpec(async_epochs=True),
                   mesh=jax.make_mesh((1, 1), ("data", "model")))


# ---------------------------------------------------------------------------
# AMB-DG reference: staleness-D convergence on the quadratic objective
# ---------------------------------------------------------------------------

def _setup(n=10, b_global=600, d=64):
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (d,))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    t = amb_budget_from_fmb(model, n, b_global)
    # beta must dominate the delay: the k=1 schedule of the sequential
    # tests is delay-5 unstable (eta_1 = 0.5 > the ~0.3 stability bound);
    # k=2/scale=2 is stable through staleness 4
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t, comm_time=2.0 * t,      # long consensus window
        fmb_batch_per_node=b_global // n, graph="paper",
        consensus_rounds=5,
        beta=BetaSchedule(k=2.0, mu=float(b_global), scale=2.0))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    return obj, w_star, model, cfg, eval_fn


def test_delayed_gradients_converge_on_quadratic():
    """Staleness-D AMB-DG still drives the quadratic to its noise floor,
    and the bounded-staleness schedule shrinks per-epoch wall time to
    max(T, T_c/D)."""
    obj, w_star, model, cfg, eval_fn = _setup()
    kw = dict(epochs=60, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    start = float(eval_fn(obj.init_w()))
    floor = 0.5 * obj.noise_var
    walls = {}
    for d in (1, 2, 4):
        h = run_amb_delayed(obj, model, cfg, staleness=d, **kw)
        tail = float(h.eval_loss[-10:].mean())
        # within ~an order of magnitude of the irreducible noise floor
        # (0.0005 here), four orders below the init loss (~35)
        assert tail < 1e-3 * start and tail < 15.0 * floor, (d, tail)
        walls[d] = float(h.wall_time[-1])
        np.testing.assert_allclose(
            walls[d],
            60 * max(cfg.compute_time, cfg.comm_time / d), rtol=1e-5)
    # T_c = 2T: D=2 is compute-bound, sequential-window regret reclaimed
    assert walls[2] < walls[1] and walls[4] == walls[2]


def test_delayed_staleness_one_comparable_to_pipelined():
    """At D=1 the delayed-gradient chain tracks the staleness-1 pipelined
    reference to the same convergence regime (not bit-equal — pipelining
    additionally harvests comm-window gradients)."""
    obj, w_star, model, cfg, eval_fn = _setup()
    kw = dict(epochs=60, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_d = run_amb_delayed(obj, model, cfg, staleness=1, **kw)
    h_p = run_amb_pipelined(obj, model, cfg, **kw)
    tail_d = float(h_d.eval_loss[-10:].mean())
    tail_p = float(h_p.eval_loss[-10:].mean())
    assert tail_d < 3.0 * max(tail_p, 0.5 * obj.noise_var)


def test_delayed_rejects_zero_staleness():
    obj, w_star, model, cfg, eval_fn = _setup()
    with pytest.raises(ValueError):
        run_amb_delayed(obj, model, cfg, staleness=0, epochs=1,
                        key=jax.random.PRNGKey(0), sample_args=(w_star,))


# ---------------------------------------------------------------------------
# Restore round trip on a trivial in-process mesh
# ---------------------------------------------------------------------------

def test_restore_roundtrip_tiny(tmp_path):
    """Save mid-run (async queue in flight), restore, finish: identical
    trajectory to the uninterrupted session — including the in-flight
    consensus payloads and the step counter."""
    from test_api import _tiny_session
    from repro.api import AMBSession
    from repro.data import LMTokenStream

    cons = ConsensusSpec(consensus="gossip", async_epochs=True, staleness=2)
    ref, cfg = _tiny_session(cons)
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8, seed=0)
    ref_losses = [ref.step(stream.batch(0, i, ref.global_batch))["loss"]
                  for i in range(4)]
    ref.flush()

    part, _ = _tiny_session(cons)
    for i in range(2):
        part.step(stream.batch(0, i, part.global_batch))
    part.save(tmp_path)
    assert (tmp_path / "session.json").exists()
    assert (tmp_path / "step_00000002").exists()          # primal layout
    assert (tmp_path / "session_state" / "step_00000002").exists()

    rest = AMBSession.restore(tmp_path, mesh=part.mesh, cfg=cfg)
    assert rest.steps_done == 2
    assert rest.sim_wall == part.sim_wall
    got = [rest.step(stream.batch(0, i, rest.global_batch))["loss"]
           for i in range(2, 4)]
    assert got == ref_losses[2:], (got, ref_losses)
    rest.flush()
    err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(jax.tree.leaves(ref.params),
                              jax.tree.leaves(rest.params)))
    assert err == 0.0, err


# ---------------------------------------------------------------------------
# Golden parity + mesh restore (slow, forced-host-device subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_staleness_one_is_pipelined_bit_for_bit():
    """The correctness anchor: AsyncProtocol(staleness=1) and
    PipelinedProtocol produce identical per-step losses AND bit-identical
    post-flush parameters on a real 4x2 mesh (8 forced host devices),
    for both fp32 and quantized gossip."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.data import LMTokenStream

        SEQ, BPW, STEPS = 32, 2, 3
        train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=SEQ,
                          batch_per_worker=BPW, data=4, model=2)
        clock = ClockSpec(kind="simulated")

        def drive(cons):
            s = AMBSession(train, clock, cons)
            stream = LMTokenStream(vocab_size=s.cfg.vocab_size,
                                   seq_len=SEQ, seed=0)
            losses = [s.step(stream.batch(0, i, s.global_batch))["loss"]
                      for i in range(STEPS)]
            s.flush()
            return s, losses

        for consensus in ("gossip", "gossip_q8"):
            sp, lp = drive(ConsensusSpec(consensus=consensus,
                                         gossip_rounds=4, pipeline=True))
            sa, la = drive(ConsensusSpec(consensus=consensus,
                                         gossip_rounds=4,
                                         async_epochs=True, staleness=1))
            assert lp == la, (consensus, lp, la)
            err = max(float(jnp.abs(a - b).max()) for a, b in
                      zip(jax.tree.leaves(sp.params),
                          jax.tree.leaves(sa.params)))
            assert err == 0.0, (consensus, err)
            print("BITWISE", consensus, err)
    """)
    assert out.count("BITWISE") == 2


@pytest.mark.slow
def test_async_staleness_mesh_behaviour():
    """Staleness-D semantics on the mesh: the first D-1 settles are
    no-ops (duals only move from step D on), deeper staleness changes
    the trajectory from step D on, flush drains a partially-warm queue,
    and a mid-flight save/restore resumes the losses exactly."""
    out = run_sub("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.data import LMTokenStream

        SEQ, BPW = 32, 2
        train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=SEQ,
                          batch_per_worker=BPW, data=4, model=2)
        clock = ClockSpec(kind="simulated")
        cons = lambda d: ConsensusSpec(consensus="gossip", gossip_rounds=4,
                                       async_epochs=True, staleness=d)

        s3 = AMBSession(train, clock, cons(3))
        stream = LMTokenStream(vocab_size=s3.cfg.vocab_size, seq_len=SEQ,
                               seed=0)
        # the payload of epoch k settles at epoch k + D: through step
        # D - 1 only zero pre-fill slots reach the settle, so the dual
        # replicas stay at zero
        for i in range(3):
            s3.step(stream.batch(0, i, s3.global_batch))
            z_mag = max(float(jnp.abs(z).max())
                        for z in jax.tree.leaves(s3.state["z"]))
            assert z_mag == 0.0, (i, z_mag)
        s3.step(stream.batch(0, 3, s3.global_batch))  # epoch-0 payload lands
        z_mag = max(float(jnp.abs(z).max())
                    for z in jax.tree.leaves(s3.state["z"]))
        assert z_mag > 0.0
        # flush drains the partially-warm queue: queue zero, t preserved
        s3.flush()
        assert all(float(jnp.abs(q).max()) == 0.0
                   for q in s3.state["queue"])
        assert int(s3.state["t"]) == 4

        # gradients at step t see messages through t - D - 1: D=2 and
        # D=3 agree on losses while both see none (steps 0..2), and
        # split at step 3 (D=2 sees epoch 0's consensus, D=3 does not)
        l2, l3 = [], []
        a2, a3 = AMBSession(train, clock, cons(2)), \
                 AMBSession(train, clock, cons(3))
        for i in range(4):
            batch = stream.batch(0, i, a2.global_batch)
            l2.append(a2.step(batch)["loss"])
            l3.append(a3.step(batch)["loss"])
        assert l2[:3] == l3[:3], (l2, l3)
        assert l2[3] != l3[3], (l2, l3)
        print("STALENESS_OK")

        # mid-flight save/restore resumes exactly (queue carried over)
        ref = AMBSession(train, clock, cons(2))
        want = [ref.step(stream.batch(0, i, ref.global_batch))["loss"]
                for i in range(4)]
        part = AMBSession(train, clock, cons(2))
        for i in range(2):
            part.step(stream.batch(0, i, part.global_batch))
        with tempfile.TemporaryDirectory() as d:
            part.save(d)
            rest = AMBSession.restore(d)
        got = [rest.step(stream.batch(0, i, rest.global_batch))["loss"]
               for i in range(2, 4)]
        assert got == want[2:], (got, want)
        ref.flush(); rest.flush()
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(ref.params),
                      jax.tree.leaves(rest.params)))
        assert err == 0.0, err
        print("RESTORE_OK")
    """)
    assert "STALENESS_OK" in out and "RESTORE_OK" in out
