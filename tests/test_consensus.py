"""Consensus: graphs, doubly-stochastic P, gossip convergence (paper §3, Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import consensus as cns


GRAPH_CASES = [("ring", 6), ("ring", 11), ("complete", 8), ("star", 7),
               ("paper", 10), ("torus", 12), ("erdos_renyi", 9)]


@pytest.mark.parametrize("name,n", GRAPH_CASES)
def test_graphs_connected_symmetric(name, n):
    adj = cns.build_graph(name, n)
    assert adj.shape == (n, n)
    assert not adj.diagonal().any()
    assert (adj == adj.T).all()
    assert cns.is_connected(adj)


@pytest.mark.parametrize("name,n", GRAPH_CASES)
def test_metropolis_doubly_stochastic_psd(name, n):
    p = cns.metropolis_weights(cns.build_graph(name, n), lazy=0.5)
    assert np.allclose(p.sum(0), 1.0)
    assert np.allclose(p.sum(1), 1.0)
    assert (p >= -1e-12).all()
    ev = np.linalg.eigvalsh(p)
    assert ev.min() >= -1e-9          # PSD (paper requires PSD P)
    assert cns.lambda2(p) < 1.0       # connected -> spectral gap


def test_paper_graph_lambda2_matches_paper():
    """App. I.1 reports lambda_2 = 0.888 for the 10-node topology."""
    p = cns.metropolis_weights(cns.paper_graph(), lazy=cns.PAPER_GRAPH_LAZY)
    assert abs(cns.lambda2(p) - 0.888) < 0.002


def test_gossip_preserves_mean_and_converges():
    n, d = 10, 7
    p = jnp.asarray(cns.metropolis_weights(cns.paper_graph()), jnp.float32)
    msgs = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    mean = msgs.mean(0)
    for r in (1, 5, 25):
        out = cns.gossip(msgs, p, r)
        # doubly-stochastic -> mean preserved exactly
        np.testing.assert_allclose(np.asarray(out.mean(0)), np.asarray(mean),
                                   rtol=1e-5, atol=1e-5)
    err1 = float(cns.consensus_error(cns.gossip(msgs, p, 1)))
    err40 = float(cns.consensus_error(cns.gossip(msgs, p, 40)))
    # geometric decay at rate lambda_2 (paper graph: 0.888^39 ~ 1e-2)
    assert err40 < 0.05 * err1


def test_gossip_per_node_rounds():
    """Nodes that stop early keep stale values; uniform per-node counts
    reduce to the scalar-rounds case."""
    n, d = 6, 3
    p = jnp.asarray(cns.metropolis_weights(cns.ring_graph(n)), jnp.float32)
    msgs = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    out = cns.gossip(msgs, p, jnp.array([0, 1, 2, 3, 4, 5]), max_rounds=5)
    # node with r_i = 0 keeps its initial message
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(msgs[0]),
                               rtol=1e-6)
    # uniform per-node counts == scalar rounds
    out_u = cns.gossip(msgs, p, jnp.full((n,), 3), max_rounds=3)
    np.testing.assert_allclose(np.asarray(out_u),
                               np.asarray(cns.gossip(msgs, p, 3)), rtol=1e-5)


def test_lemma1_round_bound_achieves_epsilon():
    """Running the Lemma-1 number of rounds achieves eps accuracy."""
    n = 10
    p_np = cns.metropolis_weights(cns.paper_graph())
    p = jnp.asarray(p_np, jnp.float32)
    lip = 1.0
    eps = 0.05
    r = cns.lemma1_rounds(n, lip, eps, p_np)
    # messages with norm <= L (the Lemma's setting after normalisation)
    msgs = jax.random.normal(jax.random.PRNGKey(2), (n, 4))
    msgs = msgs / jnp.linalg.norm(msgs, axis=1, keepdims=True) * lip
    out = cns.gossip(msgs, p, r)
    exact = cns.exact_average(msgs)
    err = float(jnp.max(jnp.linalg.norm(out - exact, axis=1)))
    assert err <= eps


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 12), st.integers(0, 10))
def test_gossip_sum_invariance_property(n, seed):
    """Column-stochasticity: the (weighted) sum of messages is invariant —
    the property that makes AMB's b-weighted consensus correct."""
    adj = cns.ring_graph(n)
    p = jnp.asarray(cns.metropolis_weights(adj), jnp.float32)
    msgs = jax.random.normal(jax.random.PRNGKey(seed), (n, 5))
    out = cns.gossip(msgs, p, 7)
    np.testing.assert_allclose(np.asarray(out.sum(0)),
                               np.asarray(msgs.sum(0)), rtol=2e-4, atol=2e-4)
