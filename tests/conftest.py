import os

# Tests run on the single real CPU device; ONLY subprocess-based distribution
# tests force a device count (never set globally here, per the dry-run
# contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
