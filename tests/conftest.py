import os
import random
import sys
import types

# Tests run on the single real CPU device; ONLY subprocess-based distribution
# tests force a device count (never set globally here, per the dry-run
# contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis shim: this container cannot pip-install, so when hypothesis is
# absent we register a minimal API-compatible stand-in (seeded random
# sampling, `max_examples` draws per test) under the same module name BEFORE
# test modules are collected.  Property tests keep running — with less
# adversarial example search — instead of failing at import.
# ---------------------------------------------------------------------------

def _install_hypothesis_shim():
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_with(self, rng):
            return self._draw(rng)

    def integers(min_value=0, max_value=1 << 31):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def just(value):
        return _Strategy(lambda rng: value)

    def lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.example_with(rng)
            for _ in range(rng.randint(min_size, max_size))])

    def tuples(*elems):
        return _Strategy(
            lambda rng: tuple(e.example_with(rng) for e in elems))

    def given(*gargs, **gkwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_shim_max_examples", 20)
                rng = random.Random(0xA3B)
                for _ in range(n):
                    ex_args = tuple(s.example_with(rng) for s in gargs)
                    ex_kwargs = {k: s.example_with(rng)
                                 for k, s in gkwargs.items()}
                    fn(*args, *ex_args, **kwargs, **ex_kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._shim_wrapped = fn
            return wrapper
        return deco

    def settings(max_examples=20, **_):
        def deco(fn):
            # applies below OR above @given; thread through either way
            target = getattr(fn, "_shim_wrapped", fn)
            target._shim_max_examples = max_examples
            fn._shim_max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [("integers", integers), ("floats", floats),
                      ("booleans", booleans), ("sampled_from", sampled_from),
                      ("just", just), ("lists", lists), ("tuples", tuples)]:
        setattr(st_mod, name, obj)

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
