"""repro.control: telemetry, policies, the Controller, and session wiring.

Host-level tests cover the telemetry EMAs (including the measured-tau
preference that keeps the Lemma-6 re-solve out of its positive feedback
loop), the three policies' proposals, the Controller's cadence /
hysteresis / rate limits, and the JSON + argparse spec round-trips.  The
session-level tests drive a tiny in-process AMBSession with a mis-tuned
budget and assert the controller pulls T to the Lemma-6 solve; the slow
subprocess test (8 forced host devices) covers the acceptance criterion:
a controller-raised staleness change mid-run is bit-exactly resumable
through ``save`` / ``restore``.

Satellite coverage for :class:`repro.api.clock.MeasuredClock` lives here
too: EMA warm-up from ``sec_per_grad=None``, b_i(t) convergence under a
hardware speed step-change, and the ``ClockSpec.ema`` round-trip.
"""
import argparse
import json

import jax
import numpy as np
import pytest

from repro.api import ClockSpec, ControllerSpec, MeasuredClock, make_clock
from repro.control import (BatchDampingPolicy, BudgetPolicy, ControlAction,
                           Controller, EpochRecord, StalenessPolicy,
                           Telemetry)
from repro.core.stragglers import (ShiftedExponential, amb_batch_sizes,
                                   amb_budget_from_fmb)

from test_dist import run_sub      # canonical forced-device subprocess


def _record(t, budget=4.0, comm=2.0, b=(8, 8, 8, 8), loss=1.0, **kw):
    return EpochRecord(t=t, budget_s=budget, comm_time_s=comm, step_s=0.01,
                       loss=loss, b=np.asarray(b),
                       global_batch=float(np.sum(b)), **kw)


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

def test_telemetry_ema_folds():
    tel = Telemetry(ema=0.5)
    tel.update(_record(0, budget=4.0, b=(2, 4, 8, 8)))
    # fallback estimator: mean_i T / b_i
    want = np.mean(4.0 / np.array([2, 4, 8, 8.0]))
    assert tel.tau == pytest.approx(want)
    assert tel.ratio == pytest.approx(0.5)
    tel.update(_record(1, budget=4.0, b=(4, 4, 4, 4)))
    assert tel.tau == pytest.approx(0.5 * want + 0.5 * 1.0)
    assert tel.epochs_seen == 2


def test_telemetry_prefers_measured_tau():
    """When b_i saturates the data cap, T/b_i over-bills the fast nodes;
    a supplied measured tau_s must win over the fallback."""
    tel = Telemetry(ema=0.5)
    tel.update(_record(0, budget=40.0, b=(8, 8, 8, 8), tau_s=1.25))
    assert tel.tau == pytest.approx(1.25)        # not 40 / 8 = 5.0
    assert tel.ratio == pytest.approx(2.0 / 40.0)


def test_telemetry_noise_scale():
    """McCandlish form: tr(Sigma) = Dw B/(n-1), ||g||^2 debiased."""
    tel = Telemetry(ema=0.0)     # ema=0 -> last observation wins
    tel.update(_record(0, b=(8, 8, 8, 8), grad_sq_norm=2.0, grad_var=0.3))
    big_b, n = 32.0, 4
    tr = 0.3 * big_b / (n - 1)
    g2 = 2.0 - 0.3 / (n - 1)
    assert tel.trace_sigma == pytest.approx(tr)
    assert tel.grad_sq == pytest.approx(g2)
    assert tel.noise_scale == pytest.approx(tr / g2)
    # state round-trip restores every EMA exactly
    back = Telemetry.from_state(tel.to_state())
    assert back.to_state() == tel.to_state()


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def test_budget_policy_solve_is_lemma6():
    pol = BudgetPolicy(b_target=600)
    tau, n = 0.02, 10
    want = (1.0 + n / 600.0) * (600.0 / n) * tau
    assert pol.solve(tau, n) == pytest.approx(want)
    # per-call b_target override (the batch-damping hook)
    assert pol.solve(tau, n, b_target=1200) == pytest.approx(
        (1.0 + n / 1200.0) * (1200.0 / n) * tau)


def test_budget_policy_stationary_matches_lemma6():
    """The jit EMA form (the old AdaptiveBudget API, now re-exported from
    repro.control) converges to Lemma 6's T on a stationary cluster."""
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    n, b_global = 10, 600
    pol = BudgetPolicy(b_target=b_global, ema=0.8)
    t_lemma6 = amb_budget_from_fmb(model, n, b_global)
    state = pol.init(10.0 * t_lemma6)            # start badly mis-tuned
    key = jax.random.PRNGKey(4)
    for t in range(40):
        times = model.per_gradient_times(jax.random.fold_in(key, t), n,
                                         4 * (b_global // n))
        b = amb_batch_sizes(times, float(state["t_budget"]))
        state = pol.update(state, b)
    assert abs(float(state["t_budget"]) - t_lemma6) / t_lemma6 < 0.25


def test_adaptive_budget_is_an_alias():
    from repro.core.extensions import AdaptiveBudget
    assert AdaptiveBudget is BudgetPolicy


def test_staleness_policy_hysteresis():
    sp = StalenessPolicy(d_max=8, hysteresis=0.25)
    # ideal D = ceil(ratio) clipped to [1, d_max]
    assert [sp.target(r) for r in (0.1, 1.0, 1.5, 2.0, 4.2, 99.0)] == \
        [1, 1, 2, 2, 5, 8]
    # raises only past d_cur + hyst; lowers only past d_cur - 1 - hyst
    assert [sp.propose(2, r) for r in (0.4, 1.9, 2.1, 2.3, 4.2)] == \
        [1, 2, 2, 3, 5]
    # a boundary ratio never thrashes between adjacent values
    d = 2
    for _ in range(6):
        d = sp.propose(d, 2.0)
    assert d == 2
    assert StalenessPolicy.gamma(1) == 1.0
    assert StalenessPolicy.gamma(4) == pytest.approx(1.0 / 8.0)


def test_batch_damping_policy():
    pol = BatchDampingPolicy(b_floor=64, b_cap=512, grow=2.0, deadband=0.25)
    assert pol.propose(64, None) == 64           # no telemetry yet
    assert pol.propose(64, 1000.0) == 128        # rate-limited to 2x
    assert pol.propose(128, 1000.0) == 256
    assert pol.propose(400, 1000.0) == 512       # hard cap
    assert pol.propose(64, 70.0) == 64           # inside the deadband
    assert pol.propose(256, 1.0) == 256          # grow-only: never shrinks


# ---------------------------------------------------------------------------
# Controller: cadence, decisions, state round-trip
# ---------------------------------------------------------------------------

def _controller(async_mode=True, **spec_kw):
    kw = dict(enabled=True, interval=2, warmup=3)
    kw.update(spec_kw)
    return Controller(ControllerSpec(**kw), n_workers=4, comm_time=8.0,
                      b_target=32, b_cap=32, staleness=1,
                      async_mode=async_mode)


def test_controller_warmup_and_cadence():
    ctl = _controller()
    acts = [ctl.observe(_record(t, budget=40.0, b=(4,) * 4, tau_s=1.0))
            for t in range(8)]
    # nothing during warmup; then at most one decision per interval
    assert acts[0] is None and acts[1] is None
    fired = [i for i, a in enumerate(acts) if a is not None]
    assert fired, "controller never acted on a 10x mis-tuned budget"
    assert all(b - a >= 2 for a, b in zip(fired, fired[1:]))


def test_controller_budget_and_staleness_decisions():
    """Mis-tuned T=40 with true tau=1: budget falls (rate-limited 2x per
    decision) toward Lemma 6 ~ 9, and D rises once T_c/T demands it."""
    ctl = _controller()
    for t in range(20):
        ctl.observe(_record(t, budget=ctl.budget or 40.0, b=(4,) * 4,
                            tau_s=1.0))
    want = BudgetPolicy(b_target=32).solve(1.0, 4)
    # converges to the solve, up to the anti-thrash deadband (10%)
    assert ctl.budget == pytest.approx(want, rel=0.15)
    # T ~ 9, T_c = 8 -> ratio < 1 + hyst: D must still be 1...
    assert ctl.staleness == 1
    ctl2 = _controller()
    ctl2.comm_time = 80.0        # ...but a 10x window forces deep staleness
    for t in range(20):
        ctl2.observe(_record(t, budget=ctl2.budget or 40.0, comm=80.0,
                             b=(4,) * 4, tau_s=1.0))
    assert ctl2.staleness == 8   # d_max-clipped
    assert ctl2.decisions > 0


def test_controller_staleness_suppressed_outside_async():
    ctl = _controller(async_mode=False)
    for t in range(20):
        ctl.observe(_record(t, budget=ctl.budget or 1.0, comm=80.0,
                            b=(4,) * 4, tau_s=1.0))
    assert ctl.staleness == 1    # sequential/pipelined: D is not a knob


def test_controller_state_roundtrip_replays_identically():
    """to_state/load_state is the bit-exact-resume contract: two
    controllers fed the same tail from a shared snapshot must decide
    identically."""
    recs = [_record(t, budget=40.0, b=(3, 4, 5, 4), tau_s=1.0 + 0.01 * t)
            for t in range(12)]
    a = _controller()
    for r in recs[:6]:
        a.observe(r)
    snap = json.loads(json.dumps(a.to_state()))   # through JSON, as saved
    b = _controller()
    b.load_state(snap)
    rest_a = [None if x is None else x.to_dict()
              for x in (a.observe(r) for r in recs[6:])]
    rest_b = [None if x is None else x.to_dict()
              for x in (b.observe(r) for r in recs[6:])]
    assert rest_a == rest_b


def test_control_action_nontrivial():
    assert not ControlAction(epoch=1).nontrivial
    assert ControlAction(epoch=1, budget=2.0).nontrivial
    assert ControlAction(epoch=1, staleness=2, gamma=0.25).nontrivial


# ---------------------------------------------------------------------------
# ControllerSpec + ClockSpec.ema round-trips (satellite)
# ---------------------------------------------------------------------------

def test_controller_spec_roundtrips():
    spec = ControllerSpec(enabled=True, interval=3, warmup=7, d_max=4)
    assert ControllerSpec.from_json(spec.to_json()) == spec
    ap = argparse.ArgumentParser()
    ClockSpec.add_cli_args(ap)
    ControllerSpec.add_cli_args(ap)
    args = ap.parse_args(["--controller", "--controller-interval", "3",
                          "--controller-warmup", "7",
                          "--controller-dmax", "4", "--clock-ema", "0.55"])
    assert ControllerSpec.from_args(args) == spec
    # ClockSpec.ema round-trips through argparse and JSON
    clk = ClockSpec.from_args(args)
    assert clk.ema == 0.55
    assert ClockSpec.from_json(clk.to_json()) == clk
    # defaults parse to the default (disabled) spec
    assert ControllerSpec.from_args(ap.parse_args([])) == ControllerSpec()


# ---------------------------------------------------------------------------
# MeasuredClock (satellite): warm-up, convergence, EMA wiring
# ---------------------------------------------------------------------------

def test_measured_clock_warms_up_from_model_unit():
    clk = make_clock(ClockSpec(kind="measured", ema=0.5), n=4,
                     batch_per_worker=8)
    assert isinstance(clk, MeasuredClock)
    assert clk.sec_per_grad is None              # no measurement yet
    _, b0 = clk.epoch(jax.random.PRNGKey(0))
    assert b0 == pytest.approx((1.0 + 4 / 32) * clk.model_unit * 8)
    clk.update(step_seconds=16.0, global_b=32.0)   # 0.5 s per gradient
    assert clk.sec_per_grad == pytest.approx(0.5)  # first obs adopted


def test_measured_clock_tracks_speed_step_change():
    """Hardware gets 4x faster mid-run: the EMA converges and b_i(t) at a
    *fixed* budget grows accordingly."""
    clk = make_clock(ClockSpec(kind="measured", ema=0.5), n=4,
                     batch_per_worker=16)
    for _ in range(4):
        clk.update(step_seconds=64.0, global_b=64.0)   # 1 s / grad
    t_lemma6 = clk.budget()
    t_fixed = t_lemma6 / 4.0     # under-provisioned: b_i well below cap
    b_slow = int(amb_batch_sizes(clk.times(jax.random.PRNGKey(0)),
                                 t_fixed).sum())
    for _ in range(12):
        clk.update(step_seconds=16.0, global_b=64.0)   # 0.25 s / grad
    assert clk.sec_per_grad == pytest.approx(0.25, rel=0.01)
    b_fast = int(amb_batch_sizes(clk.times(jax.random.PRNGKey(0)),
                                 t_fixed).sum())
    assert b_fast > 2 * b_slow       # same T, ~4x the gradients (capped)
    # and the re-derived Lemma-6 budget shrank with the unit
    assert clk.budget() == pytest.approx(t_lemma6 / 4, rel=0.02)


def test_clock_set_budget_pins():
    clk = make_clock(ClockSpec(kind="measured"), n=4, batch_per_worker=8)
    clk.set_budget(2.5)
    clk.update(step_seconds=80.0, global_b=8.0)  # would re-derive T = 90
    _, budget = clk.epoch(jax.random.PRNGKey(0))
    assert budget == 2.5                         # pinned: controller owns T
    sim = make_clock(ClockSpec(kind="simulated"), n=4, batch_per_worker=8)
    sim.set_budget(1.25)
    assert sim.epoch(jax.random.PRNGKey(0))[1] == 1.25


# ---------------------------------------------------------------------------
# Session wiring (tiny in-process mesh)
# ---------------------------------------------------------------------------

def _tiny_controlled_session(clock, controller, consensus=None,
                             metrics_path=None):
    from repro.api import AMBSession, ConsensusSpec, TrainSpec
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    train = TrainSpec(batch_per_worker=2, seq_len=8)
    cons = consensus or ConsensusSpec(consensus="gossip", gossip_rounds=2)
    return AMBSession(train, clock, cons, controller, mesh=mesh,
                      cfg=cfg, metrics_path=metrics_path), cfg


def test_session_controller_corrects_mistuned_budget(tmp_path):
    """A 10x over-provisioned simulated budget is pulled to ~Lemma 6, and
    both the epochs and the decisions land in the metrics JSONL."""
    from repro.data import LMTokenStream
    from repro.metrics import read_metrics
    session, cfg = _tiny_controlled_session(
        ClockSpec(kind="simulated", compute_time=40.0, comm_time=0.5),
        ControllerSpec(enabled=True, interval=1, warmup=2),
        metrics_path=tmp_path / "m.jsonl")
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8, seed=0)
    budgets = []
    for i in range(10):
        m = session.step(stream.batch(0, i, session.global_batch))
        budgets.append(m["budget_s"])
    session.close()
    # Lemma 6 for this clock's model at n=1, b=2
    t_lemma6 = amb_budget_from_fmb(session.clock.model, 1, 2)
    assert budgets[0] == 40.0
    assert abs(budgets[-1] - t_lemma6) / t_lemma6 < 0.5, budgets
    recs = read_metrics(tmp_path / "m.jsonl")
    assert len(recs) == 10
    assert any("action" in r for r in recs)
    assert all("loss" in r and "budget_s" in r for r in recs)


def test_session_without_controller_unchanged(tmp_path):
    """Default sessions carry no controller and no noise-stats graph —
    the opt-in leaves the bit-parity surface untouched."""
    session, _ = _tiny_controlled_session(
        ClockSpec(kind="simulated"), None)
    assert session.controller is None
    assert session.protocol.amb.noise_stats is False


@pytest.mark.slow
def test_controller_staleness_retune_resumes_bit_exact():
    """Acceptance: the controller raises D mid-run (long T_c), and a
    save/restore through that retuned state continues bit-for-bit."""
    out = run_sub("""
        import tempfile
        import jax
        from repro.api import (AMBSession, ClockSpec, ConsensusSpec,
                               ControllerSpec, TrainSpec)
        from repro.data import LMTokenStream

        train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=16,
                          batch_per_worker=2, data=4, model=2)
        clock = ClockSpec(kind="simulated", comm_time=12.0)
        cons = ConsensusSpec(consensus="gossip", gossip_rounds=2,
                             async_epochs=True, staleness=1)
        ctl = ControllerSpec(enabled=True, interval=1, warmup=2)
        s = AMBSession(train, clock, cons, ctl)
        stream = LMTokenStream(vocab_size=s.cfg.vocab_size, seq_len=16,
                               seed=0)
        for i in range(6):
            m = s.step(stream.batch(0, i, s.global_batch))
        assert m["staleness"] > 1, m["staleness"]   # D was raised mid-run
        d = tempfile.mkdtemp()
        s.save(d)
        ref = [s.step(stream.batch(0, i, s.global_batch))["loss"]
               for i in range(6, 10)]
        r = AMBSession.restore(d)
        got = [r.step(stream.batch(0, i, r.global_batch))["loss"]
               for i in range(6, 10)]
        assert ref == got, (ref, got)
        print("BITEXACT D=", s.consensus_spec.staleness)
    """)
    assert "BITEXACT" in out
