"""Fast in-process checks of repro.dist: mesh gossip vs core consensus,
seq-weight masking properties, and the exact step on the trivial mesh.

These run on the single real CPU device (no subprocess / forced device
count) — the cross-implementation contracts that test_dist.py then proves
on real multi-device meshes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import consensus as cns
from repro.dist.amb import (num_workers, ring_gossip, ring_p,
                            seq_weights_from_b, worker_axes)


# ---------------------------------------------------------------------------
# ring_gossip == core.consensus.gossip (same P, same rounds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,rounds", [(2, 1), (4, 4), (4, 25), (8, 7),
                                      (10, 12)])
def test_ring_gossip_matches_core_gossip(n, rounds):
    """The mesh-layout gossip (rolled neighbor stacks + K-way weighted
    combine) and the dense P @ m reference are the same operator."""
    msgs = jax.random.normal(jax.random.PRNGKey(n * 100 + rounds), (n, 33))
    p = jnp.asarray(ring_p(n), jnp.float32)
    want = cns.gossip(msgs, p, rounds)
    got = ring_gossip(msgs, rounds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_ring_gossip_preserves_mean_and_contracts():
    n = 6
    msgs = jax.random.normal(jax.random.PRNGKey(3), (n, 17))
    out = ring_gossip(msgs, 30)
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(msgs.mean(0)), rtol=1e-5,
                               atol=1e-5)
    assert float(cns.consensus_error(out)) < 0.1 * float(
        cns.consensus_error(msgs))


def test_ring_gossip_single_worker_identity():
    msgs = jnp.ones((1, 5)) * 3.0
    np.testing.assert_array_equal(np.asarray(ring_gossip(msgs, 10)),
                                  np.asarray(msgs))


def test_ring_p_doubly_stochastic():
    for n in (2, 3, 4, 16):
        p = ring_p(n)
        assert np.allclose(p.sum(0), 1.0) and np.allclose(p.sum(1), 1.0)


# ---------------------------------------------------------------------------
# seq_weights_from_b properties (paper eq. 3 masking)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 2 ** 30))
def test_seq_weights_properties(n, per, seed):
    """sum(w) == sum(min(b_i, per)); each worker block is a 0/1 prefix."""
    rng = np.random.default_rng(seed)
    b = rng.integers(0, per + 3, size=n)          # may exceed capacity
    gb = n * per
    w = np.asarray(seq_weights_from_b(jnp.asarray(b, jnp.int32), gb, n))
    assert w.shape == (gb,)
    assert set(np.unique(w)) <= {0.0, 1.0}
    assert w.sum() == np.minimum(b, per).sum()
    blocks = w.reshape(n, per)
    for i in range(n):
        k = int(blocks[i].sum())
        assert (blocks[i][:k] == 1.0).all() and (blocks[i][k:] == 0.0).all()
        assert k == min(int(b[i]), per)


def test_seq_weights_rejects_indivisible():
    with pytest.raises(ValueError):
        seq_weights_from_b(jnp.zeros((3,), jnp.int32), 10, 3)


# ---------------------------------------------------------------------------
# worker accounting on meshes (real single-device + fake shapes)
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_num_workers_spans_non_model_axes():
    assert num_workers(FakeMesh({"data": 4, "model": 2})) == 4
    assert num_workers(FakeMesh({"pod": 2, "data": 2, "model": 2})) == 4
    assert num_workers(FakeMesh({"model": 8})) == 1
    assert worker_axes(FakeMesh({"pod": 2, "data": 2, "model": 2})) == \
        ("pod", "data")


def test_exact_step_trivial_mesh_descends():
    """make_train_step on the 1x1 mesh (single real device): the full AMB
    masking/metrics path without any parallelism."""
    from repro.dist import use_sharding
    from repro.dist.amb import AMBConfig, make_train_step
    from repro.data import LMTokenStream
    from repro.models import init_params
    from repro.models.common import ArchConfig
    from repro.optim import make_optimizer

    cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64,
                     num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
                     vocab_size=128, q_chunk=32, kv_chunk=32,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    opt = make_optimizer("adamw", lr=1e-2)
    with use_sharding(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt, mesh, AMBConfig()))
        losses = []
        for i in range(8):
            batch = stream.batch(0, i, 4)
            params, state, m = step(params, state, batch,
                                    jnp.array([3], jnp.int32))
            losses.append(float(m["loss"]))
        assert m["global_batch"] == 3
        assert losses[-1] < losses[0]


def test_gossip_step_zero_batch_preserves_duals():
    """A straggler-wiped epoch (every b_i(t) = 0) must leave the gossip dual
    state unchanged — the exact-consensus path sees a zero gradient there,
    and the decentralized path has to agree, not reset z to 0."""
    from repro.dist import use_sharding
    from repro.dist.amb import AMBConfig, make_gossip_train_step
    from repro.core.dual_averaging import BetaSchedule
    from repro.data import LMTokenStream
    from repro.models import init_params
    from repro.models.common import ArchConfig

    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    amb = AMBConfig(consensus="gossip", gossip_rounds=2,
                    beta=BetaSchedule(k=5.0, mu=1.0, scale=10.0))
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8, seed=0)
    with use_sharding(mesh):
        init_state, gstep = make_gossip_train_step(cfg, mesh, amb)
        state = init_state(init_params(jax.random.PRNGKey(0), cfg))
        batch = stream.batch(0, 0, 2)
        state, _ = gstep(state, batch, jnp.array([2], jnp.int32))
        znorm = sum(float(jnp.abs(z).sum()) for z in
                    jax.tree.leaves(state["z"]))
        assert znorm > 0
        state2, m = gstep(state, batch, jnp.array([0], jnp.int32))
        for a, bz in zip(jax.tree.leaves(state["z"]),
                         jax.tree.leaves(state2["z"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bz))
        assert float(m["global_batch"]) == 0.0
