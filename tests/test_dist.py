"""Distributed AMB: mesh train steps, gossip consensus, param specs.

Multi-device cases run in a subprocess with forced host devices so the main
pytest process keeps the single real device (the dry-run contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.params import param_spec
from jax.sharding import PartitionSpec as P


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_spec_rules():
    mesh = FakeMesh({"data": 16, "model": 16})
    assert param_spec("embed", (256000, 12288), mesh) == P("model", "data")
    assert param_spec("unembed", (12288, 256000), mesh) == P("data", "model")
    assert param_spec("blocks/attn/wq", (64, 12288, 12288), mesh) == \
        P(None, "data", "model")
    assert param_spec("blocks/attn/wo", (64, 12288, 12288), mesh) == \
        P(None, "model", "data")
    assert param_spec("blocks/moe/w_gate", (48, 128, 2048, 768), mesh) == \
        P(None, "model", "data", None)
    assert param_spec("blocks/ln1", (64, 12288), mesh) == P()


def test_param_spec_divisibility_dropped():
    mesh = FakeMesh({"data": 16, "model": 16})
    # whisper vocab 51865 not divisible by 16 -> vocab axis dropped
    spec = param_spec("embed", (51865, 512), mesh)
    assert spec == P(None, "data")


def test_seq_weights_from_b():
    from repro.dist.amb import seq_weights_from_b
    w = seq_weights_from_b(jnp.array([2, 0, 3, 1]), 16, 4)
    want = [1, 1, 0, 0, 0, 0, 0, 0, 1, 1, 1, 0, 1, 0, 0, 0]
    np.testing.assert_array_equal(np.asarray(w), want)


@pytest.mark.slow
def test_exact_train_step_descends_on_mesh():
    """Distributed-step machinery: variable-b masking, sharding, descent.

    Descent is asserted on a FIXED held-out batch (online per-step loss is
    dominated by batch noise) with AdamW; dual-averaging *convergence* is
    covered by core/engine tests on the paper's convex problems, so here we
    only assert the exact-consensus DA path runs and accumulates duals.
    """
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.dist import use_sharding
        from repro.dist.amb import AMBConfig, make_train_step
        from repro.dist.params import tree_shardings
        from repro.data import LMTokenStream, shard_batch
        from repro.models import init_params, lm_loss
        from repro.optim import make_optimizer
        from repro.core.dual_averaging import BetaSchedule

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2-1.5b")
        opt = make_optimizer("adamw", lr=3e-3)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        eval_batch = stream.batch(999, 0, 32)
        with use_sharding(mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            params = jax.tree.map(jax.device_put, params,
                                  tree_shardings(params, mesh))
            state = opt.init(params)
            step = jax.jit(make_train_step(cfg, opt, mesh, AMBConfig()))
            eval_loss = jax.jit(lambda p: lm_loss(p, cfg, eval_batch)[0])
            e0 = float(eval_loss(params))
            for i in range(30):
                batch = shard_batch(stream.batch(0, i, 8), mesh)
                b = jnp.array([2, 1, 2, 2], jnp.int32)   # variable minibatch
                params, state, m = step(params, state, batch, b)
            e1 = float(eval_loss(params))
        assert m["global_batch"] == 7
        print("E0", e0, "E1", e1)
        assert e1 < e0 - 0.05

        # dual-averaging exact path: runs on mesh, z accumulates, loss finite
        da = make_optimizer("dual_averaging",
                            beta=BetaSchedule(k=20.0, mu=1.0, scale=50.0))
        with use_sharding(mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            params = jax.tree.map(jax.device_put, params,
                                  tree_shardings(params, mesh))
            state = da.init(params)
            step = jax.jit(make_train_step(cfg, da, mesh, AMBConfig()))
            for i in range(3):
                batch = shard_batch(stream.batch(0, i, 8), mesh)
                b = jnp.array([2, 1, 2, 2], jnp.int32)
                params, state, m = step(params, state, batch, b)
        assert jnp.isfinite(m["loss"])
        znorm = sum(float(jnp.linalg.norm(z.astype(jnp.float32)))
                    for z in jax.tree.leaves(state["z"]))
        print("ZN", znorm)
        assert znorm > 0
    """)
    assert "E0" in out and "ZN" in out


@pytest.mark.slow
def test_gossip_train_step_on_mesh():
    """Decentralized gossip path correctness on a mesh:

    1. finite rounds (r=4): runs, weighted global-batch accounting is right,
       and per-worker replicas genuinely differ (eps > 0, Lemma 1 regime);
    2. many rounds (r=60): per-worker duals collapse to consensus (spread
       ~ 0) AND match the exact-consensus (eps = 0) path's dual after one
       step — the paper's eq. (4) weighted average, two implementations.
    """
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.dist import use_sharding
        from repro.dist.amb import (AMBConfig, make_gossip_train_step,
                                    make_train_step)
        from repro.dist.params import tree_shardings
        from repro.data import LMTokenStream, shard_batch
        from repro.models import init_params
        from repro.optim import make_optimizer
        from repro.core.dual_averaging import BetaSchedule

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2-1.5b")
        beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        batch0 = stream.batch(0, 0, 8)
        b = jnp.array([2, 1, 2, 2], jnp.int32)

        with use_sharding(mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            params = jax.tree.map(jax.device_put, params,
                                  tree_shardings(params, mesh))

            # exact-consensus reference: dual after one step
            opt = make_optimizer("dual_averaging", beta=beta)
            step = jax.jit(make_train_step(cfg, opt, mesh, AMBConfig()))
            _, st_e, m_e = step(params, opt.init(params),
                                shard_batch(batch0, mesh), b)

            # (1) finite rounds
            amb4 = AMBConfig(consensus="gossip", gossip_rounds=4, beta=beta)
            init_state, gstep = make_gossip_train_step(cfg, mesh, amb4)
            gs, m = jax.jit(gstep)(init_state(params),
                                   shard_batch(batch0, mesh), b)
            assert float(m["global_batch"]) == 7.0
            assert jnp.isfinite(m["loss"])
            spread4 = max(float(jnp.std(z.astype(jnp.float32), axis=0).max())
                          for z in jax.tree.leaves(gs["z"]))
            print("spread4", spread4)
            assert spread4 > 1e-7   # finite-round error is real

            # (2) many rounds -> consensus == exact path
            amb60 = AMBConfig(consensus="gossip", gossip_rounds=60, beta=beta)
            init_state, gstep = make_gossip_train_step(cfg, mesh, amb60)
            gs, _ = jax.jit(gstep)(init_state(params),
                                   shard_batch(batch0, mesh), b)
            spread60 = max(float(jnp.std(z.astype(jnp.float32), axis=0).max())
                           for z in jax.tree.leaves(gs["z"]))
            print("spread60", spread60)
            assert spread60 < 1e-6
            err = max(float(jnp.max(jnp.abs(ze - zg[0])))
                      for ze, zg in zip(jax.tree.leaves(st_e["z"]),
                                        jax.tree.leaves(gs["z"])))
            print("err", err)
            assert err < 2e-3   # bf16 grads + reduction-order differences
    """)
    assert "spread60" in out and "err" in out


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """run_one end-to-end on a reduced mesh: proves the dry-run machinery."""
    out = run_sub("""
        import os
        os.environ["REPRO_DRYRUN_XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_DRYRUN_MESH"] = "4,2"
        from pathlib import Path
        from repro.launch.dryrun import run_one
        rec = run_one("whisper-base", "train_4k", False,
                      Path("/tmp/dryrun_test"))
        assert rec["hlo_flops"] > 0
        assert rec["collectives"]["traffic_bytes"] >= 0
        assert rec["dominant_term"] in ("compute", "memory", "collective")
        print("OK", rec["dominant_term"], rec["depth_extrapolated"])
    """, devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_gossip_train_step_multi_pod():
    """3-axis mesh (pod, data, model): gossip consensus spans pod x data
    jointly — the multi-pod worker set — and batch accounting is global."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.dist import use_sharding
        from repro.dist.amb import (AMBConfig, make_gossip_train_step,
                                    num_workers)
        from repro.data import LMTokenStream, shard_batch
        from repro.models import init_params
        from repro.core.dual_averaging import BetaSchedule

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config("qwen2-1.5b")
        assert num_workers(mesh) == 4
        beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
        amb = AMBConfig(consensus="gossip", gossip_rounds=40, beta=beta)
        init_state, step = make_gossip_train_step(cfg, mesh, amb)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
        with use_sharding(mesh):
            params = init_params(jax.random.PRNGKey(0), cfg)
            state = init_state(params)
            b = jnp.array([2, 1, 2, 0], jnp.int32)   # one idle worker
            batch = shard_batch(stream.batch(0, 0, 8), mesh)
            state, m = jax.jit(step)(state, batch, b)
        assert float(m["global_batch"]) == 5.0
        assert jnp.isfinite(m["loss"])
        # 40 rounds over a 4-worker ring -> near-consensus across pods
        spread = max(float(jnp.std(z.astype(jnp.float32), axis=0).max())
                     for z in jax.tree.leaves(state["z"]))
        print("spread", spread)
        assert spread < 1e-5
    """)
    assert "spread" in out
