"""Per-arch smoke tests (required) + model-layer unit/consistency tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.core.dual_averaging import BetaSchedule
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, lm_loss, logits_fn, prefill)
from repro.models.common import apply_rope, rms_norm, scan_or_unroll, unrolled_loops
from repro.models.attention import flash_attention
from repro.models import moe as moe_mod
from repro.optim import DualAveragingOpt

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, params, b, s, key=jax.random.PRNGKey(1)):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"labels": toks}
    if cfg.input_mode == "embeds":
        batch["embeds"] = params["embed"][toks]
    else:
        batch["tokens"] = toks
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 9), (b, cfg.encoder_seq, cfg.d_model),
            cfg.jdtype)
    return batch


# ---------------------------------------------------------------------------
# REQUIRED smoke tests: reduced variant, one forward + one train step on CPU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = init_params(KEY, cfg)
    b, s = 2, 64
    batch = _batch_for(cfg, params, b, s)

    # forward: shapes + finiteness
    hidden, aux = forward(params, cfg, batch)
    assert hidden.shape == (b, s, cfg.d_model)
    logits = logits_fn(params, cfg, hidden)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one full train step (loss + grads + dual-averaging update)
    opt = DualAveragingOpt(beta=BetaSchedule(k=100.0, mu=1.0, scale=100.0))
    state = opt.init(params)

    def loss_fn(p):
        return lm_loss(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    new_params, state = opt.apply(grads, state, params)
    # params moved, no NaNs anywhere
    moved = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    b = 2
    state = init_decode_state(cfg, b, 32)
    logits, state2 = decode_step(params, cfg, state,
                                 jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(state2.pos) == 1


# ---------------------------------------------------------------------------
# consistency: train forward == token-by-token decode (per family)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen2-1.5b", "rwkv6-3b",
                                  "zamba2-1.2b", "whisper-base",
                                  "internvl2-76b"])
def test_forward_decode_consistency(arch):
    cfg = smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(KEY, cfg)
    b, s = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    batch = _batch_for(cfg, params, b, s)
    if "tokens" in batch:
        batch["tokens"] = toks
    else:
        batch["embeds"] = params["embed"][toks]
    hidden, _ = forward(params, cfg, batch)
    lt = logits_fn(params, cfg, hidden).astype(jnp.float32)

    state = init_decode_state(cfg, b, 32)
    if cfg.family == "audio":
        # decode needs the cross KV: go through prefill for the first token
        lg, state = prefill(params, cfg, {k: (v[:, :1] if k != "enc_embeds"
                                              else v)
                                          for k, v in batch.items()
                                          if k != "labels"},
                            extra_capacity=s)
        outs = [lg]
        for t in range(1, s):
            lg, state = decode_step(params, cfg, state, toks[:, t - 1])
            outs.append(lg)
        ld = jnp.stack(outs, 1)[:, :s]
        # positions shift by one relative to pure decode; compare from pos 1
        err = jnp.max(jnp.abs(lt[:, :1] - ld[:, :1]))
    else:
        outs = []
        for t in range(s):
            lg, state = decode_step(params, cfg, state, toks[:, t])
            outs.append(lg)
        ld = jnp.stack(outs, 1)
        err = jnp.max(jnp.abs(lt - ld))
    rel = float(err / (jnp.max(jnp.abs(lt)) + 1e-6))
    assert rel < 0.02, f"{arch}: decode/train mismatch rel={rel}"


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-3b", "zamba2-1.2b",
                                  "phi3.5-moe-42b-a6.6b", "whisper-base"])
def test_prefill_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(KEY, cfg)
    b, s = 2, 16
    batch = _batch_for(cfg, params, b, s)
    batch.pop("labels")
    lg_pre, state = prefill(params, cfg, batch, extra_capacity=4)
    hidden, _ = forward(params, cfg, batch)
    lg_fwd = logits_fn(params, cfg, hidden)[:, -1]
    np.testing.assert_allclose(
        np.asarray(lg_pre, np.float32), np.asarray(lg_fwd, np.float32),
        rtol=0.02, atol=0.02)
    assert int(state.pos) == s


# ---------------------------------------------------------------------------
# sliding-window / ring-cache semantics
# ---------------------------------------------------------------------------

def test_swa_ring_cache_matches_full_cache_window_mask():
    """Ring-buffer decode (O(window) memory) == full cache + window mask."""
    cfg = dataclasses.replace(smoke_config("qwen3-8b"), sliding_window=8)
    cfg_full = dataclasses.replace(cfg, sliding_window=8)
    params = init_params(KEY, cfg)
    b, steps = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, steps), 0,
                              cfg.vocab_size)

    st_ring = init_decode_state(cfg, b, 16)          # ring cap = window = 8
    assert jax.tree.leaves(st_ring.caches)[0].shape[2] == 8
    # full (linear) cache variant: window masking over a big cache
    from repro.models import attention as attn_mod
    st_full = init_decode_state(dataclasses.replace(cfg, sliding_window=0),
                                b, steps)
    st_full = jax.tree_util.tree_map(lambda x: x, st_full)

    outs_ring = []
    for t in range(steps):
        lg, st_ring = decode_step(params, cfg, st_ring, toks[:, t])
        outs_ring.append(lg)

    # reference: full forward with SWA mask
    hidden, _ = forward(params, cfg, {"tokens": toks})
    lt = logits_fn(params, cfg, hidden).astype(jnp.float32)
    lr = jnp.stack(outs_ring, 1)
    rel = float(jnp.max(jnp.abs(lt - lr)) / (jnp.max(jnp.abs(lt)) + 1e-6))
    assert rel < 0.02, f"ring SWA decode mismatch rel={rel}"


def test_long_context_config_is_subquadratic():
    cfg = get_config("qwen3-8b", shape="long_500k")
    assert cfg.sliding_window > 0
    cfg_ssm = get_config("rwkv6-3b", shape="long_500k")
    assert cfg_ssm.sliding_window == 0          # natively O(1)


# ---------------------------------------------------------------------------
# layer units
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 6, 2, 64))
    pos = jnp.arange(6)[None]
    y = apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 64))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10_000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-3


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    s = jnp.ones((32,))
    y1 = rms_norm(x, s)
    y2 = rms_norm(3.0 * x, s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_matches_dense_topk_when_no_drops():
    """With generous capacity, sort-based dispatch == naive per-token loop."""
    cfg = dataclasses.replace(smoke_config("qwen3-moe-30b-a3b"),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(6)
    p = moe_mod.moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          cfg.jdtype)
    out, aux = moe_mod.moe_forward(p, x, cfg)

    # naive reference
    xf = x.reshape(-1, cfg.d_model)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.float32)
        for j in range(cfg.experts_per_token):
            e = int(idx[t, j])
            g = jax.nn.silu(xf[t] @ p["w_gate"][e]) * (xf[t] @ p["w_up"][e])
            acc += float(gate[t, j]) * (g @ p["w_down"][e]).astype(jnp.float32)
        outs.append(acc)
    want = jnp.stack(outs).reshape(2, 8, cfg.d_model)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=0.05, atol=0.05)
    assert 0.5 < float(aux) < 4.0    # load-balance loss near its floor of 1


def test_flash_attention_jnp_unroll_equivalence():
    """scan_or_unroll must not change flash attention numerics."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 70, 2, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 70, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 70, 2, 32))
    a = flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                        q_chunk=32, kv_chunk=32)
    with unrolled_loops():
        b = flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                            q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_lm_loss_seq_weights_equal_weighted_mean():
    """AMB's masked weighted loss == manual weighted mean of per-seq losses
    (the identity that makes the exact-consensus pjit path faithful)."""
    cfg = smoke_config("qwen2-1.5b")
    params = init_params(KEY, cfg)
    b, s = 4, 32
    batch = _batch_for(cfg, params, b, s)
    w = jnp.array([1.0, 0.0, 1.0, 1.0])
    loss_w, m = lm_loss(params, cfg, batch, seq_weights=w)

    # manual: per-sequence token-NLL sums / total included tokens
    tot, cnt = 0.0, 0.0
    for i in range(b):
        sub = {k: v[i:i + 1] for k, v in batch.items()}
        li, mi = lm_loss(params, cfg, sub)
        tot += float(w[i]) * float(mi["loss"]) * float(mi["ntok"])
        cnt += float(w[i]) * float(mi["ntok"])
    np.testing.assert_allclose(float(m["loss"]), tot / cnt, rtol=2e-3)


def test_moe_grouped_dispatch_matches_single_group():
    """(b=2, s=64) -> 2 groups of 64 tokens; with generous capacity the
    grouped dispatch must equal the single-group (decode-style) path."""
    import dataclasses as _dc
    from repro.models import moe as moe_mod
    cfg = _dc.replace(smoke_config("qwen3-moe-30b-a3b"), capacity_factor=8.0)
    key = jax.random.PRNGKey(7)
    p = moe_mod.moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model),
                          cfg.jdtype)
    out_grouped, aux1 = moe_mod.moe_forward(p, x, cfg)      # groups = 2

    # same tokens as one flat "sequence" => single group path
    x1 = x.reshape(1, 128, cfg.d_model)
    out_single, aux2 = moe_mod.moe_forward(p, x1, cfg)      # groups = 1
    np.testing.assert_allclose(
        np.asarray(out_grouped.reshape(1, 128, -1), np.float32),
        np.asarray(out_single, np.float32), rtol=0.05, atol=0.05)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)
