"""The repro.api Session surface: specs, clocks, protocol, elasticity.

Fast in-process tests cover the spec round-trips (JSON + argparse), the
tri-state compute_time contract (an explicit 0.0 is honoured), the
zero-step no-op session, and the masked-subgraph consensus operator.
The golden-parity suite at the bottom (slow, forced-host-device
subprocess) asserts that an AMBSession-driven run reproduces the
pre-redesign ``launch/train.py`` wiring bit-for-bit in every consensus
mode, and that ``set_active`` is exactly the b_i(t) = 0 path.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AMBSession, ClockSpec, ConsensusSpec, MeasuredClock,
                       SimulatedClock, TrainSpec, build_protocol, make_clock)
from repro.core.stragglers import amb_batch_sizes

from test_dist import run_sub      # canonical forced-device subprocess


# ---------------------------------------------------------------------------
# Specs: JSON + argparse round-trips
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    specs = [
        TrainSpec(arch="rwkv6-3b", smoke=True, data=4, model=2, pod=2,
                  optimizer="adamw", mode="fmb", seed=7),
        ClockSpec(kind="simulated", compute_time=0.0, comm_time=1.5,
                  straggler="deterministic"),
        ConsensusSpec(consensus="gossip_q4", graph="torus",
                      torus_shape=(2, 4), pipeline=True, gossip_rounds=9,
                      beta_mu=16.0),
    ]
    for spec in specs:
        s = spec.to_json()
        back = type(spec).from_json(s)
        assert back == spec, (spec, back)
        assert back.to_json() == s        # stable fixed point
    # tuples survive the JSON list round-trip
    cs = ConsensusSpec.from_json(
        ConsensusSpec(torus_shape=(2, 4)).to_json())
    assert cs.torus_shape == (2, 4)


def test_spec_argparse_roundtrip():
    ap = argparse.ArgumentParser()
    TrainSpec.add_cli_args(ap)
    ClockSpec.add_cli_args(ap)
    ConsensusSpec.add_cli_args(ap)

    # defaults parse to the default specs
    args = ap.parse_args([])
    assert TrainSpec.from_args(args) == TrainSpec()
    assert ClockSpec.from_args(args) == ClockSpec()
    assert ConsensusSpec.from_args(args) == ConsensusSpec()

    # a full CLI line reconstructs the exact spec triple
    args = ap.parse_args([
        "--arch", "qwen2-1.5b", "--smoke", "--data", "4", "--model", "2",
        "--batch-per-worker", "2", "--seq-len", "32", "--seed", "3",
        "--sim-clock", "--compute-time", "0.0", "--comm-time", "2.0",
        "--consensus", "gossip", "--graph", "torus",
        "--gossip-rounds", "7", "--pipeline"])
    train = TrainSpec.from_args(args)
    assert train == TrainSpec(arch="qwen2-1.5b", smoke=True, data=4,
                              model=2, batch_per_worker=2, seq_len=32,
                              seed=3)
    clock = ClockSpec.from_args(args)
    assert clock.kind == "simulated"       # --sim-clock alias
    assert clock.compute_time == 0.0       # explicit zero survives
    assert clock.comm_time == 2.0
    cons = ConsensusSpec.from_args(args)
    assert cons == ConsensusSpec(consensus="gossip", graph="torus",
                                 gossip_rounds=7, pipeline=True)
    # CLI -> spec -> JSON -> spec closes the loop
    assert TrainSpec.from_json(train.to_json()) == train


# ---------------------------------------------------------------------------
# Clock: tri-state compute_time (the falsy-zero fix)
# ---------------------------------------------------------------------------

def test_explicit_zero_compute_time_is_honoured():
    key = jax.random.PRNGKey(0)
    for kind in ("simulated", "measured"):
        clk = make_clock(ClockSpec(kind=kind, compute_time=0.0), n=4,
                         batch_per_worker=8)
        times, budget = clk.epoch(key)
        assert budget == 0.0, (kind, budget)
        # T = 0 means nobody finishes a gradient — the b_i(t) = 0 epoch
        assert int(amb_batch_sizes(times, budget).sum()) == 0
    # and resolve_budget is the canonical tri-state helper
    assert ClockSpec(compute_time=0.0).resolve_budget(3.5) == 0.0
    assert ClockSpec(compute_time=None).resolve_budget(3.5) == 3.5


def test_measured_clock_budget_tracks_updates():
    clk = make_clock(ClockSpec(kind="measured"), n=4, batch_per_worker=8)
    assert isinstance(clk, MeasuredClock)
    _, b0 = clk.epoch(jax.random.PRNGKey(0))
    assert b0 > 0.0                     # pre-measurement boot (model unit)
    clk.update(step_seconds=32.0, global_b=32.0)   # 1 s per gradient
    _, b1 = clk.epoch(jax.random.PRNGKey(1))
    # Lemma-6 budget in measured units: (1 + n/b) * sec_per_grad * bpw
    assert b1 == pytest.approx((1.0 + 4 / 32) * 1.0 * 8.0)
    sim = make_clock(ClockSpec(kind="simulated"), n=4, batch_per_worker=8)
    assert isinstance(sim, SimulatedClock)
    sim.update(1.0, 1.0)                # no-op by contract
    _, bs = sim.epoch(jax.random.PRNGKey(0))
    assert bs == sim.budget_t


# ---------------------------------------------------------------------------
# Session basics on a trivial in-process mesh
# ---------------------------------------------------------------------------

def _tiny_session(consensus=ConsensusSpec(), clock=None, seed=0):
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    train = TrainSpec(batch_per_worker=2, seq_len=8, seed=seed)
    return AMBSession(train, clock or ClockSpec(kind="simulated"),
                      consensus, mesh=mesh, cfg=cfg), cfg


def test_zero_step_session_is_a_noop(tmp_path):
    """No step ever runs: params are the init, flush/save still work."""
    from repro.models import init_params
    session, cfg = _tiny_session()
    p0 = jax.tree.map(np.asarray, init_params(jax.random.PRNGKey(0), cfg))
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(session.params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    session.flush()                        # no in-flight consensus: no-op
    session.save(tmp_path)                 # checkpoint at step 0
    assert (tmp_path / "step_00000000").exists()
    assert session.steps_done == 0


def test_on_step_reports_zero_based_epoch_just_run():
    """``on_step(epoch, metrics)`` passes the 0-based index of the epoch
    that just finished, with ``steps_done`` already advanced past it."""
    session, _ = _tiny_session()
    seen = []
    session.run(3, on_step=lambda e, m: seen.append((e, session.steps_done)))
    assert seen == [(0, 1), (1, 2), (2, 3)]
    session.close()


def test_zero_step_train_driver_returns_none(tmp_path):
    """launch.train with --steps 0 returns None instead of raising
    UnboundLocalError (the pre-redesign bug)."""
    from repro.launch.train import main
    out = main(["--smoke", "--steps", "0", "--seq-len", "8",
                "--batch-per-worker", "1", "--sim-clock",
                "--metrics", str(tmp_path / "m.jsonl")])
    assert out is None


def test_session_modes_agree_on_single_worker():
    """n = 1: every consensus mode degenerates to the same local update,
    so one step must produce the identical loss across all of them."""
    losses = {}
    from repro.data import LMTokenStream
    for name, cons in [
        ("exact", ConsensusSpec()),
        ("gossip", ConsensusSpec(consensus="gossip", gossip_rounds=3)),
        ("gossip_q8", ConsensusSpec(consensus="gossip_q8",
                                    gossip_rounds=2)),
        ("pipelined", ConsensusSpec(consensus="gossip", pipeline=True)),
    ]:
        session, cfg = _tiny_session(cons)
        stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8,
                               seed=0)
        m = session.step(stream.batch(0, 0, session.global_batch))
        session.flush()
        losses[name] = m["loss"]
    assert len(set(losses.values())) == 1, losses


def test_gossip_rejects_non_dual_averaging():
    with pytest.raises(ValueError):
        AMBSession(TrainSpec(optimizer="adamw"),
                   ClockSpec(kind="simulated"),
                   ConsensusSpec(consensus="gossip"),
                   mesh=jax.make_mesh((1, 1), ("data", "model")))
    from repro.dist.amb import AMBConfig
    from repro.optim import AdamW
    with pytest.raises(ValueError):
        build_protocol(None, None, AMBConfig(consensus="gossip"),
                       optimizer=AdamW())


# ---------------------------------------------------------------------------
# Elastic membership: the masked consensus operator
# ---------------------------------------------------------------------------

def test_masked_metropolis_properties():
    from repro.core import consensus as cns
    from repro.dist import masked_metropolis
    adj = cns.ring_graph(6)
    active = np.array([1, 1, 0, 1, 1, 1], bool)
    p = masked_metropolis(adj, active, lazy=0.5)
    # doubly stochastic, inactive node is an identity row/column
    assert np.allclose(p.sum(0), 1.0) and np.allclose(p.sum(1), 1.0)
    assert p[2, 2] == 1.0 and np.count_nonzero(p[2]) == 1
    assert np.count_nonzero(p[:, 2]) == 1
    # active workers re-weight only surviving neighbors
    assert p[1, 2] == 0.0 and p[3, 2] == 0.0
    # a partitioned active set is rejected
    with pytest.raises(ValueError):
        masked_metropolis(adj, np.array([0, 1, 1, 0, 1, 1], bool), 0.5)


def test_masked_strategy_converges_to_active_mean():
    from repro.dist import make_strategy
    n = 6
    active = (True, True, False, True, True, True)
    msgs = jax.random.normal(jax.random.PRNGKey(0), (n, 16))
    g = make_strategy("gossip", n, rounds=300, graph="ring", active=active)
    # survivors re-lay onto a smaller ring: the masked operator stays on
    # the tap fast path instead of falling back to a dense P @ m
    from repro.dist import SurvivorTaps
    assert isinstance(g.taps, SurvivorTaps)
    out = np.asarray(g.combine(msgs))
    act = np.asarray(active)
    want = np.asarray(msgs)[act].mean(0)
    np.testing.assert_allclose(out[act],
                               np.broadcast_to(want, out[act].shape),
                               atol=1e-5)
    # the dropped worker keeps its own message verbatim
    np.testing.assert_allclose(out[2], np.asarray(msgs)[2], rtol=1e-6)


def test_set_active_masks_b_and_rebuilds():
    from repro.data import LMTokenStream
    session, cfg = _tiny_session()
    with pytest.raises(ValueError):
        session.set_active([False])          # someone must stay
    with pytest.raises(ValueError):
        session.set_active([True, True])     # wrong length
    session.set_active([True])               # all-active: no mask kept
    assert session._active is None
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=8, seed=0)
    m = session.step(stream.batch(0, 0, session.global_batch))
    assert m["b"].shape == (1,)


# ---------------------------------------------------------------------------
# Golden parity: AMBSession == the pre-redesign launch/train.py wiring
# (slow, forced-host-device subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_session_matches_pre_redesign_driver_bit_for_bit():
    """For each consensus mode, 3 AMBSession steps reproduce the exact
    per-step losses of the pre-redesign driver loop (the old main()'s
    hand wiring, replicated here against the dist primitives): same
    straggler draws, same key folding, same clock, same steps."""
    out = run_sub("""
        import time
        import jax, jax.numpy as jnp
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.api.clock import MeasuredClock
        from repro.configs import smoke_config
        from repro.core.dual_averaging import BetaSchedule
        from repro.core.stragglers import ShiftedExponential, amb_batch_sizes
        from repro.data import LMTokenStream, shard_batch
        from repro.dist import use_sharding
        from repro.dist.amb import (AMBConfig, make_gossip_train_step,
                                    make_train_step, num_workers)
        from repro.dist.params import tree_shardings
        from repro.dist.pipeline import make_pipelined_gossip_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.models import init_params
        from repro.optim import make_optimizer

        STEPS, BPW, SEQ, SEED = 3, 2, 32, 0

        def old_driver(consensus, pipeline):
            '''The pre-redesign launch/train.py main(), verbatim wiring.'''
            cfg = smoke_config("qwen2-1.5b")
            mesh = make_host_mesh(4, 2)
            n = num_workers(mesh)
            gb = n * BPW
            key = jax.random.PRNGKey(SEED)
            straggler = ShiftedExponential(lam=2.0 / 3.0, zeta=1.0,
                                           b_ref=BPW)
            clock = MeasuredClock(straggler, n, BPW)
            beta = BetaSchedule(k=50.0, mu=float(gb), scale=200.0)
            stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                   seed=SEED)
            gossip = consensus != "exact" or pipeline
            amb = AMBConfig(consensus=consensus, gossip_rounds=5,
                            graph="ring", beta=beta, seed=SEED)
            losses = []
            with use_sharding(mesh):
                params = init_params(key, cfg)
                params = jax.tree.map(
                    lambda p, sh: jax.device_put(p, sh), params,
                    tree_shardings(params, mesh))
                if gossip:
                    if pipeline:
                        init_s, gstep, flush = \
                            make_pipelined_gossip_train_step(cfg, mesh, amb)
                    else:
                        init_s, gstep = make_gossip_train_step(cfg, mesh,
                                                               amb)
                    state = init_s(params)
                    step_fn = jax.jit(gstep)
                else:
                    opt = make_optimizer("dual_averaging", beta=beta)
                    opt_state = opt.init(params)
                    step_fn = jax.jit(make_train_step(cfg, opt, mesh, amb))
                for step in range(STEPS):
                    skey = jax.random.fold_in(key, 10_000 + step)
                    times = clock.times(skey)
                    budget = clock.budget()
                    b = amb_batch_sizes(times, budget)
                    batch = shard_batch(stream.batch(0, step, gb), mesh,
                                        ("data",))
                    t0 = time.time()
                    if gossip:
                        state, m = step_fn(state, batch, b)
                    else:
                        params, opt_state, m = step_fn(params, opt_state,
                                                       batch, b)
                    losses.append(float(m["loss"]))
                    clock.update(time.time() - t0,
                                 float(m["global_batch"]))
            return losses

        def session_driver(consensus, pipeline):
            train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=SEQ,
                              batch_per_worker=BPW, data=4, model=2,
                              seed=SEED)
            session = AMBSession(train, ClockSpec(),
                                 ConsensusSpec(consensus=consensus,
                                               pipeline=pipeline))
            stream = LMTokenStream(vocab_size=session.cfg.vocab_size,
                                   seq_len=SEQ, seed=SEED)
            losses = [session.step(stream.batch(0, s,
                                                session.global_batch)
                                   )["loss"] for s in range(STEPS)]
            session.flush()
            return losses

        for consensus, pipeline in [("exact", False), ("gossip", False),
                                    ("gossip_q8", False),
                                    ("gossip", True)]:
            want = old_driver(consensus, pipeline)
            got = session_driver(consensus, pipeline)
            assert want == got, (consensus, pipeline, want, got)
            print("PARITY", consensus, "pipelined" if pipeline else "seq",
                  got)
    """)
    assert out.count("PARITY") == 4


@pytest.mark.slow
def test_set_active_equals_b_zero_on_mesh():
    """Elastic membership on a real 4x2 mesh: a dropped worker produces
    exactly the state a b_i(t) = 0 epoch would (exact consensus), and
    under gossip the dropped worker's dual replica is frozen while the
    active set keeps mixing."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
        from repro.data import LMTokenStream

        SEQ, BPW = 32, 2
        train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=SEQ,
                          batch_per_worker=BPW, data=4, model=2)
        clock = ClockSpec(kind="simulated")

        def fresh(consensus):
            return AMBSession(train, clock, ConsensusSpec(
                consensus=consensus, gossip_rounds=4))

        stream = LMTokenStream(vocab_size=fresh("exact").cfg.vocab_size,
                               seq_len=SEQ, seed=0)
        mask = [True, True, False, True]

        # exact consensus: set_active == forcing b_i(t) = 0 by hand
        sA = fresh("exact"); sA.set_active(mask)
        batch = stream.batch(0, 0, sA.global_batch)
        mA = sA.step(batch)
        assert mA["b"][2] == 0 and mA["b"].sum() > 0
        sB = fresh("exact")
        mB = sB.step(batch, b=jnp.asarray(mA["b"]))
        assert mA["loss"] == mB["loss"], (mA["loss"], mB["loss"])
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(sA.params),
                      jax.tree.leaves(sB.params)))
        assert err == 0.0, err
        print("EXACT_OK", mA["b"].tolist())

        # gossip: dropped worker is cut from the graph AND contributes 0
        sG = fresh("gossip"); sG.set_active(mask)
        z_before = [np.asarray(z)[2].copy()
                    for z in jax.tree.leaves(sG.state["z"])]
        mG = sG.step(batch)
        assert mG["b"][2] == 0
        z_after = [np.asarray(z)[2] for z in jax.tree.leaves(sG.state["z"])]
        for zb, za in zip(z_before, z_after):
            np.testing.assert_array_equal(zb, za)   # frozen while dropped
        # active workers did update
        moved = max(float(np.abs(np.asarray(z)[0]).max())
                    for z in jax.tree.leaves(sG.state["z"]))
        assert moved > 0.0
        # global batch only counts active workers
        assert mG["global_batch"] == float(mG["b"].sum())

        # the primal excludes the dropped worker's frozen dual: replacing
        # it with garbage must not move session.params at all
        p1 = [np.asarray(p) for p in jax.tree.leaves(sG.params)]
        sG.state["z"] = jax.tree.map(lambda z: z.at[2].set(1e3),
                                     sG.state["z"])
        p2 = [np.asarray(p) for p in jax.tree.leaves(sG.params)]
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(a, b)

        # rejoin: worker 2 participates again next step
        sG.set_active([True] * 4)
        mR = sG.step(stream.batch(0, 1, sG.global_batch))
        assert mR["b"][2] > 0
        print("GOSSIP_OK")
    """)
    assert "EXACT_OK" in out and "GOSSIP_OK" in out
