"""AMB/FMB engine end-to-end behaviour (paper §6 claims at test scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BetaSchedule, EngineConfig, ShiftedExponential,
                        amb_budget_from_fmb, run_amb, run_fmb)
from repro.core.objectives import LinearRegression, LogisticRegression


def _linreg_setup(d=24, n=10, b_global=200):
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(7), (d,))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=b_global // n)
    t_budget = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=128, chunk=64, compute_time=t_budget, comm_time=0.5,
        fmb_batch_per_node=b_global // n, consensus_rounds=5,
        beta=BetaSchedule(k=1.0, mu=float(b_global)))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    return obj, w_star, model, cfg, eval_fn


def test_amb_converges_linreg():
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    h = run_amb(obj, model, cfg, epochs=80, key=jax.random.PRNGKey(0),
                sample_args=(w_star,), eval_fn=eval_fn,
                f_star=0.5 * obj.noise_var)
    assert float(h.eval_loss[-1]) < 0.05 * float(h.eval_loss[0])
    assert not bool(jnp.any(jnp.isnan(h.eval_loss)))


def test_fmb_converges_and_is_slower_in_wall_time():
    """Fig. 1 analogue: similar error per epoch, AMB ahead in wall time."""
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    kw = dict(epochs=80, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_amb = run_amb(obj, model, cfg, **kw)
    h_fmb = run_fmb(obj, model, cfg, **kw)
    # comparable final error (expected batch sizes matched via Lemma 6)
    assert float(h_amb.eval_loss[-1]) < 3 * float(h_fmb.eval_loss[-1])
    # AMB finishes the same number of epochs in less wall time
    assert float(h_amb.wall_time[-1]) < float(h_fmb.wall_time[-1])
    # and the AMB epoch time is deterministic: T + T_c
    diffs = jnp.diff(h_amb.wall_time)
    np.testing.assert_allclose(np.asarray(diffs), diffs[0], rtol=1e-5)


def test_lemma6_in_engine():
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    h = run_amb(obj, model, cfg, epochs=150, key=jax.random.PRNGKey(3),
                sample_args=(w_star,), eval_fn=eval_fn)
    assert float(h.global_batch.mean()) >= 200 * 0.95


def test_consensus_error_decreases_with_rounds():
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    errs = []
    for r in (1, 3, 9):
        import dataclasses
        cfg_r = dataclasses.replace(cfg, consensus_rounds=r)
        h = run_amb(obj, model, cfg_r, epochs=30, key=jax.random.PRNGKey(0),
                    sample_args=(w_star,), eval_fn=eval_fn)
        errs.append(float(h.consensus_eps[5:].mean()))
    assert errs[0] > errs[1] > errs[2]


def test_exact_consensus_is_gossip_limit():
    import dataclasses
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    kw = dict(epochs=25, key=jax.random.PRNGKey(1), sample_args=(w_star,),
              eval_fn=eval_fn)
    h_exact = run_amb(obj, model, dataclasses.replace(
        cfg, consensus_mode="exact"), **kw)
    h_gossip = run_amb(obj, model, dataclasses.replace(
        cfg, consensus_rounds=60), **kw)
    assert float(h_exact.consensus_eps.max()) < 1e-4
    np.testing.assert_allclose(np.asarray(h_gossip.eval_loss[-5:]),
                               np.asarray(h_exact.eval_loss[-5:]),
                               rtol=0.05, atol=1e-4)


def test_regret_sublinear():
    """Cor. 3: R(tau) = O(sqrt(m)) — fitted growth exponent of regret in
    cumulative samples stays well below linear."""
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    h = run_amb(obj, model, cfg, epochs=200, key=jax.random.PRNGKey(2),
                sample_args=(w_star,), eval_fn=eval_fn,
                f_star=0.5 * obj.noise_var)
    m = np.cumsum(np.asarray(h.potential_samples))
    r = np.asarray(h.regret)
    # fit log r ~ a log m on the second half (transient discarded)
    lo = len(m) // 2
    a = np.polyfit(np.log(m[lo:]), np.log(np.maximum(r[lo:], 1e-6)), 1)[0]
    assert a < 0.75, f"regret growth exponent {a:.2f} not sublinear-ish"


def test_logreg_amb_learns():
    obj = LogisticRegression(dim=16, num_classes=4)
    means = obj.make_class_means(jax.random.PRNGKey(11))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=40)
    cfg = EngineConfig(n=5, b_max=64, chunk=32, compute_time=1.2,
                      comm_time=0.3, fmb_batch_per_node=40, graph="ring",
                      consensus_rounds=5,
                      beta=BetaSchedule(k=1.0, mu=200.0))
    kb = jax.random.PRNGKey(5)
    eval_batch = obj.sample(kb, (512,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)
    h = run_amb(obj, model, cfg, epochs=60, key=jax.random.PRNGKey(0),
                sample_args=(means,), eval_fn=eval_fn)
    assert float(h.eval_loss[-1]) < 0.6 * float(h.eval_loss[0])
