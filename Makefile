# Repro convenience targets.  `make verify` is the tier-1 gate.

.PHONY: verify verify-fast smoke controller-smoke dataplane-smoke \
        churn-smoke serve-smoke docs-check bench-dist

verify:               # docs check + smokes + full pytest suite
	scripts/verify.sh

verify-fast:          # fast lane: docs + smokes + pytest -m 'not slow'
	scripts/verify.sh --fast

smoke:                # just the programmatic-API smoke example
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m examples.api_session --smoke

controller-smoke:     # the online-controller end-to-end CI smoke
	JAX_PLATFORMS=cpu python scripts/controller_smoke.py

dataplane-smoke:      # prefetch + donation + kernel-routing CI smoke
	JAX_PLATFORMS=cpu python scripts/dataplane_smoke.py

churn-smoke:          # Poisson churn + coded redundancy CI smoke
	JAX_PLATFORMS=cpu python scripts/churn_smoke.py

serve-smoke:          # continuous batching + AMB interleave CI smoke
	JAX_PLATFORMS=cpu python scripts/serve_smoke.py

docs-check:           # README/docs references must match the code
	python scripts/check_docs.py

bench-dist:
	PYTHONPATH=src python -m benchmarks.dist_step --steps 6
