# Repro convenience targets.  `make verify` is the tier-1 gate.

.PHONY: verify verify-fast smoke bench-dist

verify:               # API smoke stage + full pytest suite
	scripts/verify.sh

verify-fast:          # fast lane: API smoke + pytest -m 'not slow'
	scripts/verify.sh --fast

smoke:                # just the programmatic-API smoke example
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m examples.api_session --smoke

bench-dist:
	PYTHONPATH=src python -m benchmarks.dist_step --steps 6
