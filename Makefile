# Repro convenience targets.  `make verify` is the tier-1 gate.

.PHONY: verify verify-fast bench-dist

verify:
	scripts/verify.sh

verify-fast:          # skip the mesh-heavy subprocess tests
	scripts/verify.sh -m 'not slow'

bench-dist:
	PYTHONPATH=src python -m benchmarks.dist_step --steps 6
