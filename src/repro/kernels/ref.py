"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<name>`` in this package has ``ref.<name>_ref`` with identical
semantics; tests sweep shapes/dtypes and assert allclose between the kernel
(interpret=True on CPU) and these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dual_update_ref(z: Array, w0: Array, beta: Array) -> Array:
    """Fused dual-averaging prox: w = w0 - z / (2 beta).  fp32 math."""
    return (w0.astype(jnp.float32)
            - z.astype(jnp.float32) / (2.0 * beta.astype(jnp.float32)))


def gossip_combine_ref(msgs: Array, weights: Array) -> Array:
    """Weighted neighbor combine: out = sum_k weights[k] * msgs[k].

    msgs: (K, N); weights: (K,).  This is one row of m <- P m restricted to
    the K in-neighborhood messages (self included).
    """
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32),
                      msgs.astype(jnp.float32))


def stochastic_quantize_ref(m: Array, h: Array, rnd: Array, lo: Array,
                            scale: Array, levels: float = 255.0):
    """Send half of a quantized gossip round (see gossip_combine kernels).

    Returns (levels (n, d) uint8, h_new (n, d) f32): stochastic rounding of
    ``m - h`` onto the row grid (lo, scale, ``levels = 2^bits - 1`` steps)
    using the uniform draws ``rnd``, plus the updated public replica
    ``h + lo + levels * scale``.
    """
    diff = m.astype(jnp.float32) - h.astype(jnp.float32)
    u = (diff - lo.astype(jnp.float32)) / scale.astype(jnp.float32)
    fl = jnp.floor(u)
    lvl = jnp.minimum(fl + (rnd < (u - fl)).astype(jnp.float32),
                      float(levels))
    h_new = h.astype(jnp.float32) + lo + lvl * scale
    return lvl.astype(jnp.uint8), h_new


def quantized_combine_ref(m: Array, hnbr: Array, lvl: Array, lo: Array,
                          scale: Array, weights: Array):
    """Receive half: dequantize K-1 neighbor deltas, update replicas, combine.

    m: (n, d); hnbr: (K-1, n, d); lvl: (K-1, n, d) uint8; lo, scale:
    (K-1, n, 1); weights: (K,).  Returns (out (n, d), hnbr_new (K-1, n, d)).
    """
    w = weights.astype(jnp.float32)
    hnbr_new = (hnbr.astype(jnp.float32)
                + lo.astype(jnp.float32)
                + lvl.astype(jnp.float32) * scale.astype(jnp.float32))
    out = w[0] * m.astype(jnp.float32)
    for j in range(hnbr.shape[0]):
        out = out + w[j + 1] * hnbr_new[j]
    return out, hnbr_new


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, q_offset: int = 0) -> Array:
    """Naive softmax attention oracle.

    q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd); GQA via H = KV * G.
    Returns (B, H, Sq, hd) in fp32.
    """
    b, h, sq, hd = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, g, sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bkch->bkgqc", qf, kf) / jnp.sqrt(hd)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(k.shape[2])
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bkch->bkgqh", p, vf)
    return out.reshape(b, h, sq, hd)


def rwkv6_chunk_ref(r: Array, k: Array, v: Array, decay: Array,
                    u: Array) -> Array:
    """RWKV6 wkv over the full sequence, chunk-free sequential oracle.

    r, k, v, decay: (B, H, S, hd); u: (H, hd) current-token bonus.
    Returns y (B, H, S, hd), fp32.  decay in (0, 1].
    """
    b, h, s, hd = r.shape
    rf, kf, vf, df = (t.astype(jnp.float32) for t in (r, k, v, decay))

    def step(state, inp):
        rt, kt, vt, dt = inp                     # (B,H,hd)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt, state + u[None, :, :, None] * kv)
        state = dt[..., None] * state + kv
        return state, y

    st0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rf, kf, vf, df))
    _, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 2, 0, 3)


def mamba2_chunk_ref(x: Array, b_mat: Array, c_mat: Array,
                     decay: Array) -> Array:
    """Mamba2/SSD sequential oracle.

    x: (B, S, H, hd) dt-scaled inputs; b_mat, c_mat: (B, S, ns);
    decay: (B, S, H) in (0,1].  Returns y (B, S, H, hd), fp32.
    """
    bsz, s, h, hd = x.shape
    ns = b_mat.shape[-1]
    xf, bf, cf, df = (t.astype(jnp.float32) for t in (x, b_mat, c_mat, decay))

    def step(state, inp):
        xt, bt, ct, dt = inp
        state = dt[..., None, None] * state + jnp.einsum(
            "bhd,bs->bhds", xt, bt)
        y = jnp.einsum("bhds,bs->bhd", state, ct)
        return state, y

    st0 = jnp.zeros((bsz, h, hd, ns), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), bf.transpose(1, 0, 2),
          cf.transpose(1, 0, 2), df.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 0, 2, 3)
