"""Pallas TPU kernel: block flash attention (fwd) with GQA / causal / SWA.

Online-softmax attention tiled for VMEM: grid (B, H, num_q_blocks,
num_kv_blocks) with the kv axis innermost; running max / denominator / output
accumulator live in VMEM scratch that persists across the kv iterations of a
(q-block, head) cell.  GQA is expressed in the BlockSpec index map (query
head h reads kv head h // group).  Block shapes default to (128, 128) —
MXU-aligned on the (q, k) contraction and lane-aligned on hd.

This is the adaptation layer of the paper's compute phase to TPU: gradients
per unit wall-clock is what AMB's fixed-T budget buys, so the attention
hot-spot is tiled for the MXU rather than ported from a CUDA flash kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, num_kv: int,
                  causal: bool, window: int, q_offset: int, kv_len: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)
    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int = 0,
                           q_offset: int = 0, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd). Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), block_q=bq, block_k=bk,
        num_kv=nk, causal=causal, window=window, q_offset=q_offset,
        kv_len=skv)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq]
