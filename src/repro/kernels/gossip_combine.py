"""Pallas TPU kernel: weighted gossip combine  out = sum_k w_k * msg_k.

One consensus round at node i is m_i <- sum_{j in N_i u {i}} P_ij m_j
(paper eq. line 16 of Alg. 1).  The K neighbor messages arrive stacked
(K, N) after the collective_permute exchange; this kernel fuses the K-way
weighted accumulation in a single VMEM pass instead of K separate
scale-and-adds over an HBM-resident model-sized buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
LANE = 128


def _kernel(msgs_ref, w_ref, o_ref, *, k: int):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(k):
        acc = acc + w_ref[0, j] * msgs_ref[j, :, :].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gossip_combine_pallas(msgs: Array, weights: Array, *,
                          block_rows: int = 512,
                          interpret: bool = False) -> Array:
    """msgs: (K, N); weights: (K,). Returns (N,) fp32."""
    k, n = msgs.shape
    pad = (-n) % LANE
    m = jnp.pad(msgs, ((0, 0), (0, pad)))
    rows = m.shape[1] // LANE
    m = m.reshape(k, rows, LANE)
    grid = -(-rows // block_rows)
    row_pad = grid * block_rows - rows
    m = jnp.pad(m, ((0, 0), (0, row_pad), (0, 0)))
    w2 = weights.astype(jnp.float32).reshape(1, k)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m.shape[1], LANE), jnp.float32),
        interpret=interpret,
    )(m, w2)
    return out.reshape(-1)[:n]
