"""Pallas TPU kernels for the mesh gossip consensus phase.

Three kernels, one per dataflow stage of a consensus round (paper Alg. 1
line 16, plus the CHOCO-style delta compression of
:func:`repro.core.extensions.gossip_quantized`):

  * :func:`gossip_combine_pallas` — fp32 K-way weighted combine
    ``out = sum_k w_k * msg_k``: the K neighbor messages arrive stacked
    (K, N) after the collective_permute exchange and the weighted
    accumulation is fused in a single VMEM pass instead of K separate
    scale-and-adds over an HBM-resident model-sized buffer.

  * :func:`stochastic_quantize_pallas` — the *send* half of a quantized
    round, fused in one pass per block: recompute ``diff = m - h``,
    stochastically round to ``levels = floor(u) + Bernoulli(frac(u))`` on
    the per-node uniform grid (lo/scale precomputed row-wide), and update
    the node's public replica ``h += lo + levels * scale``.  The uint8
    ``levels`` plane is the wire message — (32/bits)x fewer
    collective-permute bytes than the fp32 message.

  * :func:`quantized_combine_pallas` — the *receive* half, fused: for each
    of the K-1 neighbor taps, dequantize the received levels into the local
    replica ``hnbr_k += lo_k + levels_k * scale_k`` and accumulate the
    weighted combine ``out = w_0 * m + sum_k w_k * hnbr_k`` without ever
    materializing the dequantized messages in HBM.

The fusion boundary between the send and receive kernels is the ICI
exchange itself (the rolled uint8 planes); everything on either side of it
is one VMEM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
LANE = 128


def _kernel(msgs_ref, w_ref, o_ref, *, k: int):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(k):
        acc = acc + w_ref[0, j] * msgs_ref[j, :, :].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gossip_combine_pallas(msgs: Array, weights: Array, *,
                          block_rows: int = 512,
                          interpret: bool = False) -> Array:
    """msgs: (K, N); weights: (K,). Returns (N,) fp32."""
    k, n = msgs.shape
    pad = (-n) % LANE
    m = jnp.pad(msgs, ((0, 0), (0, pad)))
    rows = m.shape[1] // LANE
    m = m.reshape(k, rows, LANE)
    grid = -(-rows // block_rows)
    row_pad = grid * block_rows - rows
    m = jnp.pad(m, ((0, 0), (0, row_pad), (0, 0)))
    w2 = weights.astype(jnp.float32).reshape(1, k)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m.shape[1], LANE), jnp.float32),
        interpret=interpret,
    )(m, w2)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Quantized gossip: send half (stochastic quantize + replica update)
# ---------------------------------------------------------------------------

def _pad_rows(x: Array, block_rows: int):
    """(n, d) -> (n, rows_padded, LANE) plus the grid size along rows."""
    n, d = x.shape
    pad = (-d) % LANE
    x = jnp.pad(x, ((0, 0), (0, pad)))
    rows = x.shape[1] // LANE
    grid_r = -(-rows // block_rows)
    x = x.reshape(n, rows, LANE)
    x = jnp.pad(x, ((0, 0), (0, grid_r * block_rows - rows), (0, 0)))
    return x, grid_r


def _squantize_kernel(m_ref, h_ref, rnd_ref, lo_ref, scale_ref,
                      lvl_ref, hnew_ref, *, levels: float):
    lo = lo_ref[0, 0]
    scale = scale_ref[0, 0]
    diff = m_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    u = (diff - lo) / scale
    fl = jnp.floor(u)
    lvl = fl + (rnd_ref[...] < (u - fl)).astype(jnp.float32)
    # clamp: the row max can round to u = levels + eps; an up-round there
    # would emit 2^bits, which wraps past the top of the uint8 wire plane
    lvl = jnp.minimum(lvl, levels)
    lvl_ref[...] = lvl.astype(jnp.uint8)
    hnew_ref[...] = h_ref[...].astype(jnp.float32) + lo + lvl * scale


@functools.partial(jax.jit,
                   static_argnames=("levels", "block_rows", "interpret"))
def stochastic_quantize_pallas(m: Array, h: Array, rnd: Array, lo: Array,
                               scale: Array, *, levels: float = 255.0,
                               block_rows: int = 512,
                               interpret: bool = False):
    """Quantize ``m - h`` onto the per-row uniform grid; update the replica.

    m, h, rnd: (n, d); lo, scale: (n, 1) row-wide grid (precomputed: the
    min and (max-min)/levels of ``m - h``; ``levels = 2^bits - 1``).
    Returns ``(levels (n, d) uint8, h_new (n, d) f32)`` with
    ``levels = min(floor(u) + [rnd < frac(u)], levels)``,
    ``u = (m - h - lo)/scale``, and ``h_new = h + lo + levels * scale`` —
    bit-identical to :func:`repro.core.extensions.quantize_unbiased`
    given the same ``rnd``.
    """
    n, d = m.shape
    mp, grid_r = _pad_rows(m, block_rows)
    hp, _ = _pad_rows(h, block_rows)
    rp, _ = _pad_rows(rnd, block_rows)

    lvl, hnew = pl.pallas_call(
        functools.partial(_squantize_kernel, levels=float(levels)),
        grid=(n, grid_r),
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(mp.shape, jnp.uint8),
            jax.ShapeDtypeStruct(mp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(mp, hp, rp, lo.astype(jnp.float32), scale.astype(jnp.float32))
    unpad = lambda x: x.reshape(n, -1)[:, :d]
    return unpad(lvl), unpad(hnew)


# ---------------------------------------------------------------------------
# Quantized gossip: receive half (dequantize + combine + replica update)
# ---------------------------------------------------------------------------

def _qcombine_kernel(m_ref, hnbr_ref, lvl_ref, lo_ref, scale_ref, w_ref,
                     out_ref, hnbr_new_ref, *, k: int):
    acc = w_ref[0, 0] * m_ref[...].astype(jnp.float32)
    for j in range(k - 1):
        h = (hnbr_ref[j].astype(jnp.float32)
             + lo_ref[j, 0, 0]
             + lvl_ref[j].astype(jnp.float32) * scale_ref[j, 0, 0])
        hnbr_new_ref[j] = h
        acc = acc + w_ref[0, j + 1] * h
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantized_combine_pallas(m: Array, hnbr: Array, lvl: Array, lo: Array,
                             scale: Array, weights: Array, *,
                             block_rows: int = 512,
                             interpret: bool = False):
    """Dequantize the K-1 received neighbor deltas and combine, one pass.

    m: (n, d) self messages; hnbr: (K-1, n, d) running neighbor replicas;
    lvl: (K-1, n, d) uint8 received levels; lo, scale: (K-1, n, 1) received
    grid scalars; weights: (K,) = [P_self, P_tap_1, ...].  Returns
    ``(out (n, d) f32, hnbr_new (K-1, n, d) f32)`` with
    ``hnbr_new[k] = hnbr[k] + lo_k + lvl_k * scale_k`` and
    ``out = weights[0] * m + sum_k weights[k+1] * hnbr_new[k]``.
    """
    km1, n, d = hnbr.shape
    k = km1 + 1
    mp, grid_r = _pad_rows(m, block_rows)
    stack = lambda x, dt: jnp.stack(
        [_pad_rows(x[j].astype(dt), block_rows)[0] for j in range(km1)])
    hp = stack(hnbr, jnp.float32)
    lp = stack(lvl, jnp.uint8)
    w2 = weights.astype(jnp.float32).reshape(1, k)

    out, hnew = pl.pallas_call(
        functools.partial(_qcombine_kernel, k=k),
        grid=(n, grid_r),
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((km1, 1, block_rows, LANE),
                         lambda i, j: (0, i, j, 0)),
            pl.BlockSpec((km1, 1, block_rows, LANE),
                         lambda i, j: (0, i, j, 0)),
            pl.BlockSpec((km1, 1, 1), lambda i, j: (0, i, 0)),
            pl.BlockSpec((km1, 1, 1), lambda i, j: (0, i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((km1, 1, block_rows, LANE),
                         lambda i, j: (0, i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(mp.shape, jnp.float32),
            jax.ShapeDtypeStruct(hp.shape, jnp.float32),
        ],
        interpret=interpret,
    )(mp, hp, lp, lo.astype(jnp.float32), scale.astype(jnp.float32), w2)
    unpad = lambda x: x.reshape(*x.shape[:-2], -1)[..., :d]
    return unpad(out), unpad(hnew)
