"""Pallas TPU kernels for the perf-critical compute layers (+ oracles).

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), a jit'd wrapper in
``ops.py``, and a pure-jnp oracle in ``ref.py``; tests sweep shapes/dtypes in
interpret mode against the oracle.  ``router.py`` owns the backend routing
(compiled Pallas on TPU/GPU, jnp reference on CPU; ``REPRO_KERNELS`` /
``TrainSpec.kernels`` override), decided and logged once.
"""
from . import ops, ref, router

__all__ = ["ops", "ref", "router"]
