"""Pallas TPU kernels for the perf-critical compute layers (+ oracles).

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), a jit'd wrapper in
``ops.py``, and a pure-jnp oracle in ``ref.py``; tests sweep shapes/dtypes in
interpret mode against the oracle.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
