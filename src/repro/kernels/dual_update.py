"""Pallas TPU kernel: fused dual-averaging prox  w = w0 - z / (2 beta).

The paper's update phase (eq. 7) applied to every parameter each epoch.  It
is purely memory-bound (2 reads + 1 write per element); fusing the subtract,
scale, and dtype cast into one VMEM pass avoids materialising z/(2beta) in
HBM, which matters because z is fp32 and model-sized (the dominant optimizer
traffic term in §Roofline for train_4k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANE = 128
DEFAULT_BLOCK = 1024 * LANE      # elements per VMEM tile (512 KiB fp32)


def _kernel(z_ref, w0_ref, beta_ref, o_ref):
    beta = beta_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    o_ref[...] = w0 - z * (0.5 / beta)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dual_update_pallas(z: Array, w0: Array, beta: Array, *,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool = False) -> Array:
    """Flattens, pads to (rows, LANE) tiles, runs the fused prox.

    z: any shape (fp32 dual); w0: same shape; beta: scalar.
    Returns fp32 array of z.shape.
    """
    shape = z.shape
    n = z.size
    rows_per_block = max(block // LANE, 8)
    zf = z.reshape(-1)
    wf = w0.reshape(-1)
    pad = (-n) % LANE
    if pad:
        zf = jnp.pad(zf, (0, pad))
        wf = jnp.pad(wf, (0, pad))
    rows = zf.size // LANE
    grid = -(-rows // rows_per_block)
    row_pad = grid * rows_per_block - rows
    z2 = jnp.pad(zf.reshape(rows, LANE), ((0, row_pad), (0, 0)))
    w2 = jnp.pad(wf.reshape(rows, LANE), ((0, row_pad), (0, 0)))
    beta2 = jnp.reshape(beta.astype(jnp.float32), (1, 1))

    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pl.ANY if False else None),
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(z2.shape, jnp.float32),
        interpret=interpret,
    )(z2, w2, beta2)
    return out.reshape(-1)[:n].reshape(shape)
