"""Backend routing for the kernel layer: which implementation runs where.

Every public wrapper in :mod:`repro.kernels.ops` has three bodies — the
compiled Pallas kernel, the same kernel under ``interpret=True`` (a
debugging/oracle mode that emulates the TPU grid step by step, ~80x
slower than plain XLA at model-sized inputs; see the ``gossip_combine``
sweep in ``artifacts/bench/BENCH_dist.json``), and a pure-jnp reference.
This module owns the *routing decision* so it is made once, logged once,
and overridable in one place instead of per call site:

  * ``tpu`` / ``gpu`` backends -> ``"pallas"`` (the compiled kernel);
  * ``cpu`` (and anything else) -> ``"ref"`` — the jnp reference is real
    compiled XLA, while interpret mode must never be what a production
    step silently executes;
  * the ``REPRO_KERNELS`` environment variable or
    :func:`set_mode` (wired to ``TrainSpec.kernels`` /
    ``--kernels`` by :class:`repro.api.AMBSession`) force ``"pallas"``,
    ``"ref"``, or ``"pallas_interpret"`` anywhere — for TPU bring-up,
    CPU kernel debugging, and A/B timing.

The first resolution is logged at INFO on the ``repro.kernels`` logger;
per-call ``force=`` arguments (the test suite's oracle sweeps) bypass
the router and are never logged.
"""
from __future__ import annotations

import logging
import os
from typing import Optional

MODES = ("auto", "pallas", "ref", "pallas_interpret")
_ENV = "REPRO_KERNELS"
_PALLAS_BACKENDS = ("tpu", "gpu")

_log = logging.getLogger("repro.kernels")
_mode: Optional[str] = None         # set_mode override (spec/session)
_announced: Optional[tuple] = None  # (decision, backend) already logged


def set_mode(mode: Optional[str]) -> None:
    """Pin the routing mode programmatically (``None``/"auto" = decide
    from the backend again; logged anew on the next resolve)."""
    global _mode, _announced
    if mode is not None and mode not in MODES:
        raise ValueError(f"unknown kernel mode {mode!r}; "
                         f"choose from {MODES}")
    _mode = None if mode in (None, "auto") else mode
    _announced = None


def mode() -> str:
    """The requested mode: set_mode override, else env, else auto."""
    if _mode is not None:
        return _mode
    env = os.environ.get(_ENV, "auto")
    if env not in MODES:
        raise ValueError(f"{_ENV}={env!r} is not one of {MODES}")
    return env


def resolve(force: Optional[str] = None) -> str:
    """The implementation to run: ``pallas`` | ``ref`` |
    ``pallas_interpret``.

    ``force`` (a per-call test hook) wins and is not logged; otherwise
    the requested :func:`mode` applies, with ``auto`` routing compiled
    Pallas on TPU/GPU and the jnp reference on CPU.  The decision is
    logged once per (mode, backend) so the hot path stays silent.
    """
    if force is not None:
        if force not in MODES[1:]:
            raise ValueError(f"unknown kernel force {force!r}; "
                             f"choose from {MODES[1:]}")
        return force
    import jax
    m = mode()
    backend = jax.default_backend()
    decided = m if m != "auto" else (
        "pallas" if backend in _PALLAS_BACKENDS else "ref")
    global _announced
    if _announced != (decided, backend):
        _announced = (decided, backend)
        _log.info("kernel routing: backend=%s mode=%s -> %s "
                  "(override via %s or TrainSpec.kernels)",
                  backend, m, decided, _ENV)
    return decided
