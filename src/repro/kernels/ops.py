"""jit'd public wrappers around the Pallas kernels.

Routing policy: on TPU backends the Pallas kernel runs compiled; on CPU (this
container) the pure-jnp oracle from :mod:`ref` runs instead, and the kernels
themselves are exercised under ``interpret=True`` by the test suite.  Pass
``force="pallas_interpret"`` to exercise the kernel body anywhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .dual_update import dual_update_pallas
from .flash_attention import flash_attention_pallas
from .gossip_combine import (gossip_combine_pallas, quantized_combine_pallas,
                             stochastic_quantize_pallas)
from .rwkv6_scan import rwkv6_scan_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dual_update(z: Array, w0: Array, beta: Array,
                radius: Optional[float] = None,
                force: Optional[str] = None) -> Array:
    """w = w0 - z/(2 beta), optionally projected onto ||w - w0|| <= radius."""
    if force == "pallas_interpret":
        w = dual_update_pallas(z, w0, beta, interpret=True)
    elif force == "ref" or not _on_tpu():
        w = ref.dual_update_ref(z, w0, beta)
    else:
        w = dual_update_pallas(z, w0, beta)
    if radius is not None:
        delta = w - w0.astype(jnp.float32)
        nrm = jnp.linalg.norm(delta.reshape(-1))
        w = w0.astype(jnp.float32) + delta * jnp.minimum(
            1.0, radius / jnp.maximum(nrm, 1e-30))
    return w


def gossip_combine(msgs: Array, weights: Array,
                   force: Optional[str] = None) -> Array:
    if force == "pallas_interpret":
        return gossip_combine_pallas(msgs, weights, interpret=True)
    if force == "ref" or not _on_tpu():
        return ref.gossip_combine_ref(msgs, weights)
    return gossip_combine_pallas(msgs, weights)


def stochastic_quantize(m: Array, h: Array, rnd: Array, lo: Array,
                        scale: Array, levels: float = 255.0,
                        force: Optional[str] = None):
    """Send half of a quantized gossip round: (levels u8, updated replica)."""
    if force == "pallas_interpret":
        return stochastic_quantize_pallas(m, h, rnd, lo, scale,
                                          levels=levels, interpret=True)
    if force == "ref" or not _on_tpu():
        return ref.stochastic_quantize_ref(m, h, rnd, lo, scale, levels)
    return stochastic_quantize_pallas(m, h, rnd, lo, scale, levels=levels)


def quantized_combine(m: Array, hnbr: Array, lvl: Array, lo: Array,
                      scale: Array, weights: Array,
                      force: Optional[str] = None):
    """Receive half: fused dequantize + replica update + K-way combine."""
    if force == "pallas_interpret":
        return quantized_combine_pallas(m, hnbr, lvl, lo, scale, weights,
                                        interpret=True)
    if force == "ref" or not _on_tpu():
        return ref.quantized_combine_ref(m, hnbr, lvl, lo, scale, weights)
    return quantized_combine_pallas(m, hnbr, lvl, lo, scale, weights)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_offset: int = 0,
                    force: Optional[str] = None) -> Array:
    """(B, H, Sq, hd) x (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    if force == "pallas_interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, interpret=True)
    if force == "ref" or not _on_tpu():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset).astype(q.dtype)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)


def rwkv6_scan(r: Array, k: Array, v: Array, decay: Array, u: Array,
               force: Optional[str] = None) -> Array:
    """(BH, S, hd) wkv scan; u (BH, hd). Returns fp32 (BH, S, hd)."""
    if force == "pallas_interpret":
        return rwkv6_scan_pallas(r, k, v, decay, u, interpret=True)
    if force == "ref" or not _on_tpu():
        bh, s, hd = r.shape
        rr = lambda t: t.reshape(1, bh, s, hd)   # treat BH rows as heads
        y = ref.rwkv6_chunk_ref(rr(r), rr(k), rr(v), rr(decay),
                                u.reshape(bh, hd))
        return y.reshape(bh, s, hd)
    return rwkv6_scan_pallas(r, k, v, decay, u)
