"""jit'd public wrappers around the Pallas kernels.

Every wrapper dispatches through :mod:`repro.kernels.router` — compiled
Pallas on TPU/GPU, the pure-jnp reference on CPU (interpret mode is a
debugging oracle, never a silent production path), overridable globally
via ``REPRO_KERNELS`` / ``TrainSpec.kernels`` and per call via ``force``
(the test suite's oracle sweeps).  The routing decision is made at trace
time and logged once by the router.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref, router
from .dual_update import dual_update_pallas
from .flash_attention import flash_attention_pallas
from .gossip_combine import (gossip_combine_pallas, quantized_combine_pallas,
                             stochastic_quantize_pallas)
from .rwkv6_scan import rwkv6_scan_pallas

Array = jax.Array


def dual_update(z: Array, w0: Array, beta: Array,
                radius: Optional[float] = None,
                force: Optional[str] = None) -> Array:
    """w = w0 - z/(2 beta), optionally projected onto ||w - w0|| <= radius."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        w = dual_update_pallas(z, w0, beta, interpret=True)
    elif impl == "ref":
        w = ref.dual_update_ref(z, w0, beta)
    else:
        w = dual_update_pallas(z, w0, beta)
    if radius is not None:
        delta = w - w0.astype(jnp.float32)
        nrm = jnp.linalg.norm(delta.reshape(-1))
        w = w0.astype(jnp.float32) + delta * jnp.minimum(
            1.0, radius / jnp.maximum(nrm, 1e-30))
    return w


def gossip_combine(msgs: Array, weights: Array,
                   force: Optional[str] = None) -> Array:
    """K-way weighted combine of stacked neighbor messages: (K, N) -> (N,)."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        return gossip_combine_pallas(msgs, weights, interpret=True)
    if impl == "ref":
        return ref.gossip_combine_ref(msgs, weights)
    return gossip_combine_pallas(msgs, weights)


def stochastic_quantize(m: Array, h: Array, rnd: Array, lo: Array,
                        scale: Array, levels: float = 255.0,
                        force: Optional[str] = None):
    """Send half of a quantized gossip round: (levels u8, updated replica)."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        return stochastic_quantize_pallas(m, h, rnd, lo, scale,
                                          levels=levels, interpret=True)
    if impl == "ref":
        return ref.stochastic_quantize_ref(m, h, rnd, lo, scale, levels)
    return stochastic_quantize_pallas(m, h, rnd, lo, scale, levels=levels)


def quantized_combine(m: Array, hnbr: Array, lvl: Array, lo: Array,
                      scale: Array, weights: Array,
                      force: Optional[str] = None):
    """Receive half: fused dequantize + replica update + K-way combine."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        return quantized_combine_pallas(m, hnbr, lvl, lo, scale, weights,
                                        interpret=True)
    if impl == "ref":
        return ref.quantized_combine_ref(m, hnbr, lvl, lo, scale, weights)
    return quantized_combine_pallas(m, hnbr, lvl, lo, scale, weights)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_offset: int = 0,
                    force: Optional[str] = None) -> Array:
    """(B, H, Sq, hd) x (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, interpret=True)
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset).astype(q.dtype)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)


def rwkv6_scan(r: Array, k: Array, v: Array, decay: Array, u: Array,
               force: Optional[str] = None) -> Array:
    """(BH, S, hd) wkv scan; u (BH, hd). Returns fp32 (BH, S, hd)."""
    impl = router.resolve(force)
    if impl == "pallas_interpret":
        return rwkv6_scan_pallas(r, k, v, decay, u, interpret=True)
    if impl == "ref":
        bh, s, hd = r.shape
        rr = lambda t: t.reshape(1, bh, s, hd)   # treat BH rows as heads
        y = ref.rwkv6_chunk_ref(rr(r), rr(k), rr(v), rr(decay),
                                u.reshape(bh, hd))
        return y.reshape(bh, s, hd)
    return rwkv6_scan_pallas(r, k, v, decay, u)
