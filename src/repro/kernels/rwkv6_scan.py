"""Pallas TPU kernel: RWKV6 chunked wkv scan with data-dependent decay.

Grid (B*H, num_chunks) with the chunk axis innermost-sequential; the (hd, hd)
wkv state lives in VMEM scratch and persists across chunk iterations of one
(batch, head) cell — the TPU-native replacement for the CUDA per-timestep
recurrence: each chunk step is three (C, hd) x (hd, hd)-class matmuls on the
MXU instead of S sequential rank-1 updates.

Inputs r, k, v, decay: (B*H, S, hd) with decay in (0, 1]; u: (B*H, hd)
current-token bonus (broadcast per head outside).  Output y: (B*H, S, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _rwkv_kernel(r_ref, k_ref, v_ref, d_ref, u_ref, o_ref, state_ref, *,
                 chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros(state_ref.shape, jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    d = d_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, hd) broadcast row

    logd = jnp.log(jnp.maximum(d, 1e-20))
    cums = jnp.cumsum(logd, axis=0)           # (C, hd)
    state = state_ref[...]                    # (hd, hd)

    # Factored intra-chunk coefficients exp(cums_{t-1} - cums_u).  The clip
    # keeps each factor inside fp32; it only activates when the true
    # coefficient underflows to ~0 anyway (cumulative per-chunk decay
    # < e^-60), trading negligible precision for stability.  Default chunk
    # of 16 keeps typical RWKV6 decays far from the clip.
    rd = r * jnp.exp(jnp.clip(cums - logd, -60.0, 60.0))
    y_inter = jax.lax.dot_general(rd, state, (((1,), (0,)), ((), ())))

    kd = k * jnp.exp(jnp.clip(-cums, -60.0, 60.0))
    att = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())))   # (C, C)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    att = jnp.where(tri, att, 0.0)
    y_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())))

    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)   # (C, 1)
    y = y_inter + y_intra + bonus * v
    o_ref[0] = y.astype(o_ref.dtype)

    total = cums[-1:]                                      # (1, hd)
    wu = jnp.exp(total - cums)                             # (C, hd)
    state_ref[...] = (jnp.exp(total).T * state
                      + jax.lax.dot_general(k * wu, v,
                                            (((0,), (0,)), ((), ()))))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(r: Array, k: Array, v: Array, decay: Array, u: Array,
                      *, chunk: int = 16, interpret: bool = False) -> Array:
    """r,k,v,decay: (BH, S, hd); u: (BH, hd). Returns y (BH, S, hd) fp32."""
    bh, s, hd = r.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)
    nc = r.shape[1] // c
    u2 = u.reshape(bh, 1, hd)

    out = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=c, num_chunks=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, ci: (b, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc * c, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, decay, u2)
    return out[:, :s]
