"""Fault models: deterministic fleet-state processes for churn injection.

A :class:`FaultModel` describes *what happens to the fleet* over training
epochs — which workers are up, and how much slower than nominal each one
runs — as a pure function of the epoch index:

    ``model.fleet(epoch, n) -> FleetState(active (n,) bool, slow (n,) f32)``

Purity is the load-bearing property: the injector re-samples the fleet
state from scratch every epoch, so a restored session replays the exact
fault trajectory the saved one would have seen (bit-exact save→restore
under churn, asserted by ``scripts/churn_smoke.py``), and two runs with
the same seed see identical failures regardless of wall-clock timing.

The models compose *with* — not instead of — the existing
:class:`repro.core.stragglers.StragglerModel`: stragglers draw each
epoch's per-gradient times, fail-slow multiplies those draws (so a
degraded worker's b_i(t) shrinks through the paper's own deadline
mechanism), and fail-stop / churn removes workers entirely via
``AMBSession.set_active`` (b_i = 0 plus a consensus-operator rebuild —
the survivor-tap relayout of :mod:`repro.dist.consensus`).

Models:

  * :class:`FailStop` — named workers go down at a fixed epoch (and
    optionally come back): the deterministic unit case.
  * :class:`FailSlow` — named workers run ``factor`` x slower over an
    epoch window (a thermally-throttled or contended host).
  * :class:`PoissonChurn` — per-worker alternating renewal join/leave:
    up-times ~ Geometric(leave_rate), down-times ~ Geometric(rejoin_rate)
    (the discrete-epoch Poisson process), independent per worker from a
    per-worker seed.  ``pin`` workers never leave (the quorum anchor).
  * :class:`CorrelatedOutage` — a whole worker group drops together
    periodically (rack / power-domain failures; the case coded
    redundancy must place replicas *across* groups to survive).
  * :class:`Compose` — intersection of actives, product of slowdowns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FleetState:
    """One epoch's fleet condition: membership + speed multipliers."""

    active: np.ndarray        # (n,) bool — up this epoch
    slow: np.ndarray          # (n,) float — per-gradient time multiplier
                              # (1.0 = nominal; applies to active workers)

    @property
    def healthy(self) -> bool:
        return bool(self.active.all() and np.all(self.slow == 1.0))


def _nominal(n: int) -> FleetState:
    return FleetState(active=np.ones(n, dtype=bool),
                      slow=np.ones(n, dtype=np.float64))


class FaultModel:
    """Deterministic epoch -> :class:`FleetState` process (see module
    docstring).  Implementations must be pure in ``epoch`` — no hidden
    state — so restores replay the identical fault trajectory."""

    def fleet(self, epoch: int, n: int) -> FleetState:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FailStop(FaultModel):
    """``workers`` go down at epoch ``at``; back at ``until`` (if set)."""

    workers: Tuple[int, ...]
    at: int = 0
    until: Optional[int] = None

    def fleet(self, epoch: int, n: int) -> FleetState:
        st = _nominal(n)
        down = epoch >= self.at and (self.until is None
                                     or epoch < self.until)
        if down:
            st.active[list(self.workers)] = False
        return st


@dataclasses.dataclass(frozen=True)
class FailSlow(FaultModel):
    """``workers`` run ``factor`` x slower on epochs [start, stop)."""

    workers: Tuple[int, ...]
    factor: float = 4.0
    start: int = 0
    stop: Optional[int] = None

    def fleet(self, epoch: int, n: int) -> FleetState:
        st = _nominal(n)
        if epoch >= self.start and (self.stop is None or epoch < self.stop):
            st.slow[list(self.workers)] = float(self.factor)
        return st


@dataclasses.dataclass(frozen=True)
class PoissonChurn(FaultModel):
    """Independent per-worker alternating-renewal join/leave churn.

    Worker i (for i >= ``pin``) alternates up/down phases with
    geometrically distributed durations — mean up-time ``1/leave_rate``
    epochs, mean down-time ``1/rejoin_rate`` epochs — drawn from a
    per-worker ``default_rng((seed, i))`` stream walked from epoch 0 on
    every query (purity over speed; epochs are cheap at bench scale).
    The first ``pin`` workers never leave: the quorum anchor that keeps
    ``set_active``'s at-least-one-survivor invariant trivially true.
    """

    leave_rate: float = 0.25
    rejoin_rate: float = 0.5
    seed: int = 0
    pin: int = 1

    def fleet(self, epoch: int, n: int) -> FleetState:
        st = _nominal(n)
        for i in range(max(self.pin, 0), n):
            rng = np.random.default_rng((self.seed, i))
            t, up = 0, True
            while True:
                dur = int(rng.geometric(
                    self.leave_rate if up else self.rejoin_rate))
                if t + dur > epoch:
                    break
                t += dur
                up = not up
            st.active[i] = up
        return st


@dataclasses.dataclass(frozen=True)
class CorrelatedOutage(FaultModel):
    """``group`` drops together for ``duration`` epochs every ``period``.

    Models rack / power-domain failures: the outage window starts at
    epochs ``start, start + period, ...`` and every listed worker is
    down for the whole window — the correlated case that defeats
    same-group data placement and motivates rotating coded replicas
    across failure domains.
    """

    group: Tuple[int, ...]
    period: int = 8
    duration: int = 2
    start: int = 2

    def fleet(self, epoch: int, n: int) -> FleetState:
        st = _nominal(n)
        if epoch >= self.start \
                and (epoch - self.start) % self.period < self.duration:
            st.active[list(self.group)] = False
        return st


@dataclasses.dataclass(frozen=True)
class Compose(FaultModel):
    """AND of memberships, product of slowdowns, across ``models``."""

    models: Tuple[FaultModel, ...]

    def fleet(self, epoch: int, n: int) -> FleetState:
        st = _nominal(n)
        for m in self.models:
            sub = m.fleet(epoch, n)
            st.active[:] &= sub.active       # in-place: fields are frozen
            st.slow[:] *= sub.slow
        return st
