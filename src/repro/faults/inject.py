"""Drive a :class:`~repro.faults.models.FaultModel` through a session.

The injector is the glue between the pure fault processes and
:class:`repro.api.AMBSession`'s elastic-membership machinery.  Once per
epoch (``session.run(..., faults=...)`` calls :meth:`FaultInjector.apply`
before stepping) it:

  1. samples the epoch's :class:`~repro.faults.models.FleetState`,
  2. quorum-guards it (an all-down fleet keeps worker 0 up — AMB needs
     at least one survivor to define the epoch),
  3. on a *membership change*, calls ``session.set_active`` — which
     first **drains the in-flight consensus queue** (pipelined/async
     payloads settle under the operator they were packed for) and then
     rebuilds the gossip operator on the survivors (the relayout taps of
     :mod:`repro.dist.consensus`); a re-admitted worker resumes from its
     preserved stale dual,
  4. pins the epoch's slowdown multipliers on the session — the clock's
     per-gradient time draws are scaled per worker, so a fail-slow
     worker's b_i(t) shrinks through the paper's own deadline mechanism.

Membership events are recorded on ``injector.events`` (epoch + mask) for
benchmarks and logs.  The injector holds no model state beyond the last
applied mask, so constructing a fresh injector over the same model —
e.g. after a session restore — replays the identical trajectory.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .models import FaultModel, FleetState


class FaultInjector:
    """Apply a fault model's fleet state to a session, epoch by epoch."""

    def __init__(self, model: FaultModel):
        self.model = model
        self._mask: Optional[tuple] = None
        self._slow: Optional[tuple] = None
        self.events: list = []

    def apply(self, session, epoch: int) -> FleetState:
        """Sample epoch's fleet state and actuate it on ``session``."""
        st = self.model.fleet(int(epoch), session.n_workers)
        active = np.asarray(st.active, dtype=bool).copy()
        if not active.any():
            active[0] = True        # quorum guard: AMB needs a survivor
        mask = tuple(bool(a) for a in active)
        if mask != self._mask:
            session.set_active(active)
            self.events.append({"epoch": int(epoch),
                                "active": [int(a) for a in active]})
            self._mask = mask
        slow = tuple(float(s) for s in st.slow)
        if slow != self._slow:
            session.set_slowdown(None if all(s == 1.0 for s in slow)
                                 else st.slow)
            self._slow = slow
        return FleetState(active=active, slow=np.asarray(st.slow))

    @property
    def membership_changes(self) -> int:
        """Number of distinct membership transitions applied so far."""
        return len(self.events)
