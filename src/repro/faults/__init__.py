"""Fault injection for straggler-proof fleets (churn, outages, slowdowns).

The paper's AMB mechanism absorbs workers that are *slow* (their b_i(t)
shrinks, down to the b_i = 0 wipeout case); this package supplies the
machinery to exercise — and survive — workers that *vanish*:

  * :mod:`repro.faults.models` — pure, epoch-indexed
    :class:`FaultModel` processes (:class:`FailStop`,
    :class:`FailSlow`, :class:`PoissonChurn`,
    :class:`CorrelatedOutage`, :class:`Compose`) producing a
    :class:`FleetState` (membership mask + per-worker slowdowns) that
    composes with the existing :class:`repro.core.stragglers`
    straggler models.
  * :mod:`repro.faults.inject` — :class:`FaultInjector`, driving a
    model through :class:`repro.api.AMBSession`: membership changes go
    through ``set_active`` (drain-first flush, survivor-tap rebuild,
    dual state preserved across leave→rejoin), slowdowns scale the
    clock's per-gradient draws.

Pair with ``TrainSpec.redundancy`` (:mod:`repro.dist.redundancy`) so the
gradient estimate stays unbiased while workers are down; see the
``dist_churn`` section of ``benchmarks/dist_step.py`` for the
graceful-degradation curves and ``scripts/churn_smoke.py`` for the CI
smoke.
"""
from .models import (Compose, CorrelatedOutage, FailSlow,   # noqa: F401
                     FailStop, FaultModel, FleetState, PoissonChurn)
from .inject import FaultInjector                           # noqa: F401

__all__ = [
    "Compose", "CorrelatedOutage", "FailSlow", "FailStop", "FaultModel",
    "FaultInjector", "FleetState", "PoissonChurn",
]
