"""Staleness-1 pipelined AMB epochs on the mesh (compute/gossip overlap).

The paper's protocol leaves the ICI idle during the compute window T and
the compute units idle during the consensus window T_c.
:func:`repro.core.extensions.run_amb_pipelined` (after Al-Lawati & Draper
2020 / Dekel et al. 2012) shows the staleness-1 overlap preserves
convergence; this module is the mesh realisation: the round-r gossip of
epoch t's message runs *during* the forward/backward of epoch t+1.

Mechanically, one jitted :func:`make_pipelined_gossip_train_step` step of
epoch t:

  1. starts the consensus of the **pending** message enqueued by epoch
     t-1 (data-independent of this epoch's batch, so XLA's latency-hiding
     scheduler overlaps its collective-permutes with the backward pass),
  2. computes the local masked gradients at the *stale* primal
     ``w_i = prox(z_i(t-1))`` — the iterate each worker holds while the
     previous epoch's gossip is still in flight (staleness-1 delayed
     gradients),
  3. folds the finished consensus into the dual, and enqueues this
     epoch's message ``n b_i (z_i(t) + g_i)`` for the *next* step's
     overlap window.

``flush`` completes the last pending consensus without any new compute —
after a flush, a 1-step pipelined chain equals the sequential
:func:`repro.dist.amb.make_gossip_train_step` chain exactly (same
messages, same gossip operator, one step later); tests assert this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .amb import (AMBConfig, _init_gossip_state, _local_grads,
                  assignment_from_config, epoch_weights, grad_noise_stats,
                  num_workers, pack_messages, strategy_from_config,
                  unpack_duals, worker_axes)

Array = jax.Array


def _msg_width(params) -> int:
    """Total flattened parameter size + 1 (the appended eq.-6 scalar)."""
    return 1 + sum(int(np.prod(p.shape, dtype=np.int64))
                   for p in jax.tree.leaves(params))


def make_pipelined_gossip_train_step(cfg, mesh, amb: AMBConfig):
    """Returns (init_state, step, flush) for the pipelined AMB protocol.

    State extends the sequential gossip state with ``pending`` — the
    (n, D+1) consensus payload of the previous epoch, still "in flight".
    step(state, batch, b) -> (state, metrics); flush(state) -> state
    completes the final pending consensus (no gradients).

    Epoch t's gradients are evaluated at the staleness-1 primal (dual
    through epoch t-2's consensus) but accumulate onto the freshly agreed
    dual — the delayed-gradient semantics of
    :func:`repro.core.extensions.run_amb_pipelined`.
    """
    n = num_workers(mesh)
    waxes = worker_axes(mesh)
    beta, radius = amb.beta, amb.radius
    strategy = strategy_from_config(amb, mesh)
    assignment = assignment_from_config(amb, n)
    qkey = jax.random.PRNGKey(amb.seed)

    def init_state(params):
        from jax.sharding import NamedSharding, PartitionSpec as P
        state = _init_gossip_state(params, mesh, n, waxes)
        state["pending"] = jax.device_put(
            jnp.zeros((n, _msg_width(params)), jnp.float32),
            NamedSharding(mesh, P(waxes if n > 1 else None)))
        return state

    def _settle(state):
        """Consensus of the pending message -> the agreed dual.

        The zero "pending" of the very first epoch (and of a flushed
        state) carries a zero normaliser column, so :func:`unpack_duals`'
        empty-neighborhood guard leaves z untouched — no flag needed.

        The quantize key is derived from the *enqueuing* epoch (t - 1),
        so a pipelined chain settles each message with exactly the key
        the sequential step would have used.
        """
        out = strategy.combine(state["pending"],
                               key=jax.random.fold_in(qkey, state["t"] - 1))
        return unpack_duals(out, state["z"], n)

    def step(state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        t = state["t"]
        beta_t = beta(t.astype(jnp.float32) + 1.0)

        # (1) consensus of epoch t-1's message — no data dependency on
        # (2), so its collective-permutes overlap the backward pass.
        z_new = _settle(state)

        # (2) fwd/bwd at the stale primal prox(z(t-1)) — staleness 1.
        sw, bw = epoch_weights(b, n, per, assignment)
        grads, losses = _local_grads(cfg, state, batch, sw, beta_t, radius,
                                     n, per)

        # (3) enqueue this epoch's message on the freshly agreed dual.
        pending = pack_messages(z_new, grads, n * bw, n)

        bsum = jnp.maximum(bw.sum(), 1.0)
        metrics = {"loss": jnp.sum(bw * losses) / bsum,
                   "global_batch": bw.sum(),
                   "beta": beta(t.astype(jnp.float32) + 2.0)}
        if amb.noise_stats:
            metrics.update(grad_noise_stats(grads, bw))
        new_state = {"z": z_new, "w0": state["w0"], "t": t + 1,
                     "pending": pending}
        return new_state, metrics

    def flush(state):
        """Complete the in-flight consensus; clears the pipeline.

        ``t`` is NOT advanced: after k steps + flush the state holds the
        dual through message k — exactly the sequential chain's state at
        t = k — so downstream beta(t)-dependent consumers
        (:func:`repro.dist.amb.gossip_primal` checkpoints) agree.
        """
        z_new = _settle(state)
        return {"z": z_new, "w0": state["w0"], "t": state["t"],
                "pending": jnp.zeros_like(state["pending"])}

    return init_state, step, flush
