"""Distributed AMB on real device meshes — the production substrate.

**Programmatic use goes through** :mod:`repro.api`: construct an
:class:`repro.api.AMBSession` from :class:`repro.api.TrainSpec` /
:class:`repro.api.ClockSpec` / :class:`repro.api.ConsensusSpec` and drive
it with ``step`` / ``flush`` / ``save`` / ``params`` / ``set_active``
(see ``examples/api_session.py``).  The session owns mesh setup, param
sharding, clock construction, and epoch-driver selection; every launcher
(``repro.launch.train``, ``repro.launch.serve``, ``repro.launch.dryrun``)
and benchmark is a thin adapter over it.  This package is the substrate
the session builds on — reach for it directly only when composing new
protocols.

Layered modules (bottom up):

  * :mod:`repro.dist.sharding` — ``use_sharding(mesh)`` context +
    ``constrain`` logical-axis activation annotations (no-op off-mesh).
  * :mod:`repro.dist.params` — rule-based FSDP x TP parameter layout:
    ``param_spec(name, shape, mesh)`` and ``tree_shardings``.
  * :mod:`repro.dist.consensus` — pluggable consensus strategies on the
    per-worker message stack: ``ExactConsensus`` (eps = 0 all-reduce),
    ``GossipConsensus`` (tap-decomposed ring/torus Metropolis gossip,
    Pallas-fused combine, dense fallback for arbitrary graphs), and
    ``QuantizedGossipConsensus`` (CHOCO-style 8/4-bit delta compression,
    fused stochastic-quantize + combine kernels, barrier-pinned uint8
    wire planes); ``make_strategy`` is the factory, and an ``active``
    worker mask rebuilds the operator over the survivors for elastic
    membership — re-laid-out onto a smaller ring/torus so churned steps
    stay on the tap/collective-permute fast path (``survivor_taps``),
    with the dense ``masked_metropolis`` operator as the fallback for
    arbitrary graphs.
  * :mod:`repro.dist.redundancy` — ``CodedAssignment``: coded data
    placement (fractional-repetition groups with rotated replicas) and
    ``epoch_weights``, the decode-on-settle sequence weights that keep
    the fleet's gradient estimate unbiased when replica holders die or
    straggle (each covered sample totals weight one across survivors).
  * :mod:`repro.dist.amb` — the paper's epoch update as SPMD train
    steps: ``make_train_step`` (exact consensus, any optimizer) and
    ``make_gossip_train_step`` (per-worker dual replicas, any strategy),
    plus ``seq_weights_from_b`` (eq.-3 variable-minibatch masking),
    ``pack_messages``/``unpack_duals`` (the eq.-6 weighted payload), and
    ``num_workers`` (workers = product of non-"model" axes).
  * :mod:`repro.dist.pipeline` — ``make_pipelined_gossip_train_step``:
    the staleness-1 epoch that overlaps epoch t's round-r gossip with
    epoch t+1's forward/backward (``run_amb_pipelined`` semantics), with
    a ``flush`` that settles the final in-flight consensus.
  * :mod:`repro.dist.async_epochs` — ``make_async_gossip_train_step``:
    AMB-DG bounded-staleness delayed-gradient epochs — a queue of D
    in-flight consensus payloads generalizing the pipeline's hardcoded
    staleness 1; ``flush`` drains the queue in enqueue order.

The single-device simulator lives in :mod:`repro.core`; this package is
the same math laid out on a mesh.  The uniform TrainState + epoch-driver
wrapper over these steps is :mod:`repro.api.protocol`.
"""
from .sharding import active_mesh, constrain, use_sharding   # noqa: F401
from .params import param_spec, tree_shardings               # noqa: F401
from .consensus import (ConsensusStrategy, ExactConsensus,   # noqa: F401
                        GossipConsensus, QuantizedGossipConsensus,
                        SurvivorTaps, make_strategy, masked_metropolis,
                        survivor_taps, torus_shape_for_mesh)
from .redundancy import CodedAssignment, epoch_weights       # noqa: F401
from .amb import (AMBConfig, assignment_from_config,         # noqa: F401
                  gossip_primal,
                  make_gossip_train_step, make_train_step, num_workers,
                  pack_messages, ring_gossip, seq_weights_from_b,
                  strategy_from_config, unpack_duals, worker_axes)
from .pipeline import make_pipelined_gossip_train_step       # noqa: F401
from .async_epochs import make_async_gossip_train_step       # noqa: F401

__all__ = [
    "active_mesh", "constrain", "use_sharding", "param_spec",
    "tree_shardings", "CodedAssignment", "ConsensusStrategy",
    "ExactConsensus",
    "GossipConsensus", "QuantizedGossipConsensus", "SurvivorTaps",
    "make_strategy",
    "masked_metropolis", "survivor_taps", "torus_shape_for_mesh",
    "AMBConfig", "assignment_from_config", "epoch_weights",
    "gossip_primal",
    "make_async_gossip_train_step", "make_gossip_train_step",
    "make_pipelined_gossip_train_step",
    "make_train_step", "num_workers", "pack_messages", "ring_gossip",
    "seq_weights_from_b", "strategy_from_config", "unpack_duals",
    "worker_axes",
]
