"""Distributed AMB on real device meshes — the production substrate.

Public API:

  * :mod:`repro.dist.sharding` — ``use_sharding(mesh)`` context +
    ``constrain`` logical-axis activation annotations (no-op off-mesh).
  * :mod:`repro.dist.params` — rule-based FSDP x TP parameter layout:
    ``param_spec(name, shape, mesh)`` and ``tree_shardings``.
  * :mod:`repro.dist.amb` — the paper's epoch update as SPMD train steps:
    ``make_train_step`` (exact consensus, any optimizer),
    ``make_gossip_train_step`` (per-worker dual replicas, ring-Metropolis
    gossip over the worker axes, Pallas-fused combine), plus
    ``seq_weights_from_b`` (eq.-3 variable-minibatch masking) and
    ``num_workers`` (workers = product of non-"model" axes).

The single-device simulator lives in :mod:`repro.core`; this package is the
same math laid out on a mesh, so scaling PRs (pipelined steps, quantized
mesh gossip, multi-pod benchmarks) build here.
"""
from .sharding import active_mesh, constrain, use_sharding   # noqa: F401
from .params import param_spec, tree_shardings               # noqa: F401
from .amb import (AMBConfig, gossip_primal,                  # noqa: F401
                  make_gossip_train_step, make_train_step, num_workers,
                  ring_gossip, seq_weights_from_b, worker_axes)

__all__ = [
    "active_mesh", "constrain", "use_sharding", "param_spec",
    "tree_shardings", "AMBConfig", "gossip_primal",
    "make_gossip_train_step", "make_train_step", "num_workers",
    "ring_gossip", "seq_weights_from_b", "worker_axes",
]
