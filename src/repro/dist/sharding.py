"""Mesh-scoped sharding context + logical-axis constraint helper.

The model code never names mesh axes directly: it annotates activations with
*logical* axes (``"batch"``, ``"seq"``, ``"vocab"``, ``"expert"``) via
:func:`constrain`.  Under :func:`use_sharding` those resolve to the active
mesh's physical axes ("batch" spans the worker axes ``("pod", "data")``,
vocab/expert go on ``"model"``); outside a mesh context — or on a dimension
the mesh extent does not divide — the annotation is a no-op.  This is what
lets one forward() serve the single-device smoke tests, the 8-host-device
subprocess tests, and the 512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()

# logical activation axis -> candidate mesh axes (filtered by presence).
LOGICAL_AXES = {
    "batch": ("pod", "data"),     # AMB worker axes (data parallel)
    "seq": (),                    # no sequence parallelism (future PR)
    "vocab": ("model",),
    "expert": ("model",),
    "model": ("model",),
    "heads": ("model",),
}


def active_mesh():
    """The mesh installed by the innermost :func:`use_sharding`, or None."""
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_sharding(mesh):
    """Install ``mesh`` as the ambient mesh for :func:`constrain` calls.

    Trace-time scoped: functions jitted *and traced* inside the context bake
    the constraints in; the same code traced outside is unconstrained.
    """
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def _resolve(mesh, logical: Optional[str], dim: int):
    """Mesh axes for one logical axis on a dim of extent ``dim`` (or None)."""
    if logical is None:
        return None
    axes = tuple(a for a in LOGICAL_AXES[logical] if a in mesh.axis_names)
    if not axes:
        return None
    extent = int(np.prod([mesh.shape[a] for a in axes]))
    if extent <= 1 or dim % extent != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` under the active mesh; no-op otherwise.

    One logical name (or None) per dimension of ``x``.  Axes whose mesh
    extent does not divide the dimension are dropped (replicated) rather
    than erroring — the whisper-vocab rule, same as ``params.param_spec``.
    """
    mesh = active_mesh()
    if mesh is None or not isinstance(x, jax.core.Tracer):
        return x                      # eager or unmeshed: annotation-free
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical_axes)} logical axes for rank-{x.ndim}")
    spec = P(*(_resolve(mesh, name, d)
               for name, d in zip(logical_axes, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
