"""Bounded-staleness delayed-gradient AMB epochs (AMB-DG on the mesh).

:mod:`repro.dist.pipeline` overlaps exactly one consensus with the next
epoch's compute: staleness is hardcoded to 1.  The AMB-DG follow-up work
("Anytime Minibatch with Delayed Gradients", Al-Lawati & Draper; see
PAPERS.md) shows the dual-averaging update tolerates *D*-epoch-stale
gradients — so a consensus round that needs D compute windows to finish
can still be hidden entirely, and workers never block on the barrier.

This module generalizes the pipeline to a bounded-staleness FIFO of
``D`` in-flight consensus payloads.  One step of epoch t:

  1. **settle** the *due* payload — enqueued at epoch ``t - D``, its
     consensus has had D compute windows to complete (data-independent
     of this epoch's batch, so XLA's latency-hiding scheduler overlaps
     its collective-permutes with the backward pass),
  2. compute the local masked gradients at the **last settled dual**:
     ``w_i = prox(z_i)`` where ``z_i`` reflects payloads through epoch
     ``t - D - 1`` — delayed gradients of staleness D,
  3. **enqueue** this epoch's payload ``n b_i (z_i(t) + g_i)`` on the
     freshly settled dual at the tail of the queue.

**The settle is an increment, not a replacement — with damped mixing.**
The due payload was packed on the dual as of its enqueue epoch; the
D - 1 payloads settled while it was in flight have advanced the dual
since, so replacing the dual with the agreed value would split it into
D interleaved chains, each accumulating only every D-th gradient —
measurably divergent for D >= 2.  Instead, the payload of epoch t
carries a *mixing-damped* dual term,

    payload_i = n b_i (gamma z_i + g_i),    gamma = 1 / (2 D),

each queue slot keeps a snapshot of the dual it was packed on, and
settling applies the increment

    z_i  <-  z_i + (agreed_i - gamma snapshot_i)
          =  z_i + g_bar_w + gamma (z_bar_w - z_i)      (exact limit)

— the full-strength eq.-6 weighted-mean gradient plus a gamma-damped
pull toward the consensus dual.  The damping is what makes deep
staleness stable: a D-epoch-delayed contraction at full strength obeys
``x_t = x_{t-1} - (1 - lambda) x_{t-D}`` per gossip eigenmode, whose
roots leave the unit circle for D >= 2; damping by gamma <= 1/(2D)
keeps every mode strictly stable while the *mean* dual — what
:func:`repro.dist.amb.gossip_primal` checkpoints — still advances by
exactly the weighted-mean gradient per settle.  At ``staleness=1``
gamma = 1, the payload is the sequential ``n b_i (z_i + g_i)`` wire
format verbatim, and the settle takes the plain replacement path — the
very same :func:`repro.dist.amb.unpack_duals` graph as
:func:`repro.dist.pipeline.make_pipelined_gossip_train_step` — so
flush results are bit-for-bit equal to the pipelined protocol: the
correctness anchor ``tests/test_async.py`` asserts.

``flush`` settles the whole queue in enqueue order (no new compute) —
after a flush the state holds the dual through every enqueued payload.
The quantize key of each payload is derived from its *enqueue* epoch,
so an async chain settles every payload with exactly the key the
sequential (and staleness-1 pipelined) chain would have used,
regardless of when the settle happens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .amb import (AMBConfig, _init_gossip_state, _local_grads,
                  assignment_from_config, epoch_weights, flatten_dual,
                  grad_noise_stats, num_workers, pack_messages,
                  strategy_from_config, unflatten_dual, unpack_duals,
                  worker_axes)
from .pipeline import _msg_width

Array = jax.Array


def make_async_gossip_train_step(cfg, mesh, amb: AMBConfig,
                                 staleness: int = 1):
    """Returns (init_state, step, flush) for bounded-staleness AMB-DG.

    State extends the sequential gossip state with ``queue`` — a length-
    ``staleness`` tuple of (n, W+1) consensus payloads, oldest first —
    and, for ``staleness > 1``, ``snaps`` — the matching (n, W) dual
    snapshots each payload was packed on (slot j of a state at epoch t
    was enqueued at epoch ``t - staleness + j``).  step(state, batch, b)
    -> (state, metrics); flush(state) -> state settles the whole queue
    in enqueue order (no gradients).

    Epoch t's gradients are evaluated at the staleness-D primal (dual
    through epoch t - D - 1) and each settle applies the increment
    ``agreed - gamma * snapshot`` (see module docstring) — collapsing
    to the plain :mod:`repro.dist.pipeline` replacement at
    ``staleness=1``.
    """
    if staleness < 1:
        raise ValueError(f"staleness must be >= 1, got {staleness}")
    n = num_workers(mesh)
    waxes = worker_axes(mesh)
    beta, radius = amb.beta, amb.radius
    strategy = strategy_from_config(amb, mesh)
    assignment = assignment_from_config(amb, n)
    qkey = jax.random.PRNGKey(amb.seed)
    D = staleness
    gamma = 1.0 if D == 1 else 1.0 / (2.0 * D)   # delayed-mixing damping

    def _wshard():
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(mesh, P(waxes if n > 1 else None))

    def init_state(params):
        state = _init_gossip_state(params, mesh, n, waxes)
        w = _msg_width(params)
        zero = lambda width: jax.device_put(
            jnp.zeros((n, width), jnp.float32), _wshard())
        state["queue"] = tuple(zero(w) for _ in range(D))
        if D > 1:
            state["snaps"] = tuple(zero(w - 1) for _ in range(D))
        return state

    def _settle(z, payload, snapshot, enqueue_epoch):
        """One queued payload's consensus folded into the dual.

        A zero payload (pre-fill slots of the first D-1 epochs, or a
        flushed queue) carries a zero normaliser column; the guard turns
        it into a no-op in both branches.
        """
        out = strategy.combine(payload,
                               key=jax.random.fold_in(qkey, enqueue_epoch))
        if D == 1:
            # at D = 1 gamma = 1 and no settle intervenes between
            # enqueue and settle, so the increment equals the plain
            # replacement; taking unpack_duals keeps the exact
            # pipelined-settle graph (the bit-parity anchor)
            return unpack_duals(out, z, n)
        denom = jnp.maximum(out[:, -1:], 1e-12)
        delta = jnp.where(out[:, -1:] > 1e-6,
                          out[:, :-1] / denom - gamma * snapshot, 0.0)
        return unflatten_dual(flatten_dual(z, n) + delta, z, n)

    def step(state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        t = state["t"]
        beta_t = beta(t.astype(jnp.float32) + 1.0)

        # (1) settle the due payload, enqueued at epoch t - D — no data
        # dependency on (2), so its collective-permutes overlap the
        # backward pass.
        snap0 = state["snaps"][0] if D > 1 else None
        z_new = _settle(state["z"], state["queue"][0], snap0, t - D)

        # (2) fwd/bwd at the last settled primal prox(z) — staleness D.
        sw, bw = epoch_weights(b, n, per, assignment)
        grads, losses = _local_grads(cfg, state, batch, sw, beta_t, radius,
                                     n, per)

        # (3) enqueue this epoch's payload on the freshly settled dual
        # (gamma-damped dual term; gamma = 1 reproduces the sequential
        # wire format at D = 1).
        z_pack = z_new if D == 1 else jax.tree.map(lambda zl: gamma * zl,
                                                   z_new)
        pending = pack_messages(z_pack, grads, n * bw, n)

        bsum = jnp.maximum(bw.sum(), 1.0)
        metrics = {"loss": jnp.sum(bw * losses) / bsum,
                   "global_batch": bw.sum(),
                   "beta": beta(t.astype(jnp.float32) + 2.0)}
        if amb.noise_stats:
            metrics.update(grad_noise_stats(grads, bw))
        new_state = {"z": z_new, "w0": state["w0"], "t": t + 1,
                     "queue": state["queue"][1:] + (pending,)}
        if D > 1:
            new_state["snaps"] = state["snaps"][1:] + (
                flatten_dual(z_new, n),)
        return new_state, metrics

    def flush(state):
        """Settle every in-flight payload, oldest first; clears the queue.

        ``t`` is NOT advanced: after k steps + flush the state holds the
        dual through payload k — exactly the sequential chain's state at
        t = k — so downstream beta(t)-dependent consumers
        (:func:`repro.dist.amb.gossip_primal` checkpoints) agree.  Each
        slot settles under its own enqueue-epoch key; a partially warm
        queue (fewer than D steps taken) is handled by the zero-payload
        no-op guard, not by special cases.
        """
        z = state["z"]
        for j in range(D):
            snap = state["snaps"][j] if D > 1 else None
            z = _settle(z, state["queue"][j], snap, state["t"] - D + j)
        out = {"z": z, "w0": state["w0"], "t": state["t"],
               "queue": tuple(jnp.zeros_like(q) for q in state["queue"])}
        if D > 1:
            out["snaps"] = tuple(jnp.zeros_like(s) for s in state["snaps"])
        return out

    return init_state, step, flush
