"""Rule-based parameter sharding: name x shape -> PartitionSpec.

The layout is FSDP x TP: every weight matrix puts its d_model side on the
``"data"`` axis (fully-sharded parameters, all-gathered per layer) and its
wide side — heads, ffn, experts, vocab — on ``"model"`` (tensor parallel).
Rules are keyed by the leaf's path name so the same function shards model
params, optimizer-state mirrors of them (``z/...``, ``m/...``), and
abstract ShapeDtypeStructs identically:

  embed    (V, d)        -> P("model", "data")
  unembed  (d, V)        -> P("data", "model")
  in-proj  (d, h*hd|ff)  -> P("data", "model")      wq/wk/wv/w_gate/w_up/...
  out-proj (h*hd|ff, d)  -> P("model", "data")      wo/w_down/w_out
  moe      (E, d, ff)    -> P("model", "data", None) expert dim on "model"
  norms / biases / scalars -> replicated

A leading stacked-layer dim (anything under ``blocks``) is never sharded,
and any axis whose mesh extent does not divide the dim is dropped (the
whisper 51865-vocab rule) so indivisible shapes degrade to replication
instead of erroring.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Leaves whose *first* of the two trailing dims is the wide (TP) side.
_OUT_PROJ = frozenset({"wo", "w_down", "w_out", "decay_b"})
# MoE in-projections: (E, d, ff) — d_model is the middle dim.
_MOE_IN = frozenset({"w_gate", "w_up"})


def _keep(axis: Optional[str], dim: int, mesh) -> Optional[str]:
    """Drop an axis the mesh lacks or whose extent does not divide ``dim``."""
    if axis is None or axis not in mesh.axis_names:
        return None
    extent = int(mesh.shape[axis])
    if extent <= 1 or dim < extent or dim % extent != 0:
        return None
    return axis


def param_spec(name: str, shape, mesh,
               fsdp_axis: Optional[str] = "data") -> P:
    """PartitionSpec for the parameter at path ``name`` with ``shape``.

    ``fsdp_axis=None`` (serving) replicates the d_model side instead of
    fully sharding it; the TP side stays on "model" either way.
    """
    parts = name.split("/")
    leaf = parts[-1]
    shape = tuple(int(s) for s in shape)
    nlead = 1 if "blocks" in parts[:-1] else 0   # vmapped layer stack
    core = shape[nlead:]
    if len(core) <= 1:
        return P()                               # norms, biases, scalars

    data, model = fsdp_axis, "model"
    spec: list = [None] * len(core)
    if "moe" in parts and len(core) >= 3:
        spec[0] = model                          # expert dim
        spec[1 if leaf in _MOE_IN else len(core) - 1] = data
    elif leaf == "embed":
        spec[-2:] = [model, data]                # (vocab, d)
    elif leaf == "unembed":
        spec[-2:] = [data, model]                # (d, vocab)
    elif leaf in _OUT_PROJ:
        spec[-2:] = [model, data]
    else:
        spec[-2:] = [data, model]                # in-projections (default)

    full = [None] * nlead + spec
    return P(*(_keep(a, d, mesh) for a, d in zip(full, shape)))


def _path_name(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx",
                                                 getattr(k, "name", k)))))
    return "/".join(out)


def tree_shardings(tree, mesh, fsdp_axis: Optional[str] = "data"):
    """NamedSharding per leaf, by path-keyed :func:`param_spec` rules.

    Works on param trees, optimizer-state trees that mirror them (the rules
    key on the trailing path components), and ShapeDtypeStruct trees.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(_path_name(path), leaf.shape, mesh, fsdp_axis)),
        tree)
