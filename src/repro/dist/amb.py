"""Distributed AMB train steps on real device meshes (paper §3 -> SPMD).

This module is the thin top of a three-layer stack:

  * :mod:`repro.dist.consensus` — pluggable :class:`ConsensusStrategy`
    implementations (exact all-reduce, tap-decomposed ring/torus gossip,
    CHOCO-style 8/4-bit quantized gossip) that agree the per-worker
    message stack ``(n, D) -> (n, D)``.
  * :mod:`repro.dist.pipeline` — the staleness-1 *pipelined* epoch
    (``core.extensions.run_amb_pipelined`` semantics): round-r gossip of
    epoch t overlaps the forward/backward of epoch t+1.
  * this module — the sequential train steps, sharing the variable-
    minibatch masking (eq. 3) and the eq.-6 weighted normalisation:

      - :func:`make_train_step` — *exact consensus* (eps = 0, the
        master/worker limit): one global weighted-loss backward pass whose
        gradient is exactly ``sum_i b_i g_i / sum_i b_i``, updated by any
        :class:`repro.optim.Optimizer`.
      - :func:`make_gossip_train_step` — *decentralized consensus*
        (Lemma 1 regime): every worker keeps its own dual replica
        ``z_i``, computes its local masked gradient at its own primal
        ``w_i = prox(z_i)``, packs the messages ``n b_i (z_i + g_i)``
        with the scalar ``n b_i`` alongside (so the eq.-6 normaliser is
        itself agreed by consensus), and hands the stack to whatever
        :class:`ConsensusStrategy` the :class:`AMBConfig` names.

Workers are the product of the non-"model" mesh axes, so a multi-pod
("pod", "data", "model") mesh gossips jointly across pod x data; with
``graph="torus"`` the gossip taps follow the physical (pod, data) extents
— each roll permutes along exactly one mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import consensus as cns
from ..core.dual_averaging import BetaSchedule
from .consensus import (ConsensusStrategy, GossipConsensus, make_strategy,
                        torus_shape_for_mesh)
from .redundancy import CodedAssignment, epoch_weights

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AMBConfig:
    """Static AMB step configuration (consensus + dual-averaging knobs)."""

    consensus: str = "exact"          # exact | gossip | gossip_q8 | gossip_q4
    gossip_rounds: int = 5            # r (fp32-equivalent budget; quantized
                                      # strategies get (32/bits)x this)
    graph: str = "ring"               # worker communication graph
    torus_shape: Optional[tuple] = None   # (rows, cols); default from mesh
    lazy: float = 0.5                 # lazy-Metropolis mixing (PSD P)
    beta: BetaSchedule = BetaSchedule()   # gossip-path dual averaging
    radius: Optional[float] = None
    seed: int = 0                     # quantized-gossip PRNG stream
    active: Optional[tuple] = None    # elastic worker mask (None = all);
                                      # gossip taps rebuild on the induced
                                      # active subgraph
    noise_stats: bool = False         # emit grad_sq_norm / grad_var metrics
                                      # (repro.control telemetry); opt-in so
                                      # default step graphs stay byte-
                                      # identical
    redundancy: int = 1               # rho: coded data replication factor
                                      # (repro.dist.redundancy; 1 = uncoded,
                                      # bit-exact legacy path)
    relayout: bool = True             # elastic membership phase 2: re-lay
                                      # the survivors onto a smaller ring/
                                      # torus (taps stay collective-permute)
                                      # instead of the dense masked P @ m


def strategy_from_config(amb: AMBConfig, mesh) -> ConsensusStrategy:
    """The configured :class:`ConsensusStrategy` for this mesh's workers."""
    n = num_workers(mesh)
    tshape = amb.torus_shape
    if tshape is None and amb.graph == "torus":
        tshape = torus_shape_for_mesh(mesh)
    return make_strategy(amb.consensus, n, rounds=amb.gossip_rounds,
                         graph=amb.graph, lazy=amb.lazy, torus_shape=tshape,
                         active=amb.active, relayout=amb.relayout)


def assignment_from_config(amb: AMBConfig, n: int
                           ) -> Optional[CodedAssignment]:
    """The coded data placement, or None for the uncoded bit-exact path."""
    if amb.redundancy <= 1:
        return None
    return CodedAssignment(n, amb.redundancy)


# ---------------------------------------------------------------------------
# Workers and variable-minibatch masking
# ---------------------------------------------------------------------------

def worker_axes(mesh) -> tuple:
    """Mesh axes that enumerate AMB workers (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_workers(mesh) -> int:
    """Workers = product of the non-"model" axis extents (pod x data)."""
    return int(np.prod([int(mesh.shape[a]) for a in worker_axes(mesh)],
                       dtype=np.int64)) if worker_axes(mesh) else 1


def seq_weights_from_b(b: Array, global_batch: int, n_workers: int) -> Array:
    """Per-sequence 0/1 inclusion weights from per-worker counts b_i(t).

    The global batch is laid out in ``n_workers`` contiguous blocks of
    ``global_batch // n_workers`` sequences; worker i's first ``b_i`` slots
    are included (paper eq. 3 with static shapes).  Returns (global_batch,)
    float32.
    """
    if global_batch % n_workers:
        raise ValueError(f"global_batch {global_batch} not divisible by "
                         f"{n_workers} workers")
    per = global_batch // n_workers
    idx = jnp.arange(global_batch)
    return ((idx % per) < b[idx // per]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Ring gossip along the worker dim (compatibility wrappers)
# ---------------------------------------------------------------------------

def ring_p(n: int, lazy: float = 0.5) -> np.ndarray:
    """Lazy-Metropolis ring weights (the worker-axis P; circulant)."""
    if n < 2:
        return np.ones((1, 1))
    return cns.metropolis_weights(cns.ring_graph(n), lazy=lazy)


def ring_gossip(flat: Array, rounds: int, lazy: float = 0.5) -> Array:
    """``rounds`` rounds of ring-Metropolis gossip over dim 0 of (n, D).

    Kept as the historical entry point; now a thin wrapper over
    :class:`repro.dist.consensus.GossipConsensus` with ``graph="ring"`` —
    identical taps, identical Pallas combine, identical numerics.
    """
    return GossipConsensus(flat.shape[0], rounds, "ring", lazy).combine(flat)


# ---------------------------------------------------------------------------
# Message pack / unpack (shared with repro.dist.pipeline)
# ---------------------------------------------------------------------------

def pack_messages(z, grads, nb: Array, n: int) -> Array:
    """Stack ``n b_i (z_i + g_i)`` rows with the scalar ``n b_i`` appended.

    z / grads: trees of (n, *param) leaves; nb: (n,).  Returns (n, D+1)
    fp32 — the consensus payload whose last column carries the eq.-6
    normaliser through the same consensus operator.
    """
    leaves = jax.tree.leaves(z)
    gleaves = jax.tree.leaves(grads)
    return jnp.concatenate(
        [(nb.reshape((n,) + (1,) * (zl.ndim - 1))
          * (zl + gl.astype(jnp.float32))).reshape(n, -1)
         for zl, gl in zip(leaves, gleaves)] + [nb.reshape(n, 1)], axis=1)


def flatten_dual(z, n: int) -> Array:
    """(n, W) row-stack of a dual tree — :func:`pack_messages`' leaf
    layout, without the weight column.  The single source of truth for
    that layout, shared with :mod:`repro.dist.async_epochs`' snapshot
    increments."""
    return jnp.concatenate([zl.reshape(n, -1) for zl in jax.tree.leaves(z)],
                           axis=1)


def unflatten_dual(flat: Array, z, n: int):
    """Invert :func:`flatten_dual` onto the structure of ``z``."""
    leaves, treedef = jax.tree.flatten(z)
    sizes = [int(np.prod(l.shape[1:], dtype=np.int64)) for l in leaves]
    splits = np.cumsum(sizes)[:-1].tolist()
    return jax.tree.unflatten(treedef, [
        part.reshape((n,) + l.shape[1:])
        for part, l in zip(jnp.split(flat, splits, axis=1), leaves)])


def unpack_duals(out: Array, z, n: int):
    """Invert :func:`pack_messages` on a consensus output.

    Normalises by the agreed scalar column; a worker whose gossip
    neighborhood processed no samples (scalar ~ 0, e.g. a straggler-wiped
    epoch) keeps its dual unchanged — matching the exact path, where a
    zero gradient leaves z alone.
    """
    denom = jnp.maximum(out[:, -1:], 1e-12)
    zcat = flatten_dual(z, n)
    zflat = jnp.where(out[:, -1:] > 1e-6, out[:, :-1] / denom, zcat)
    return unflatten_dual(zflat, z, n)


# ---------------------------------------------------------------------------
# Exact-consensus train step (eps = 0)
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt, mesh, amb: AMBConfig = AMBConfig()):
    """step(params, opt_state, batch, b) -> (params, opt_state, metrics).

    ``batch`` is the global batch (leading dim sharded over the worker
    axes); ``b`` the (n_workers,) per-worker minibatch sizes for this
    epoch.  The weighted loss's gradient equals the paper's eq.-6 global
    gradient, and ``opt`` applies the update (dual averaging: z += g,
    w = prox(z, beta)).  Under coded redundancy (``amb.redundancy > 1``)
    the 0/1 eq.-3 weights become the ``1/copies`` decode weights of
    :mod:`repro.dist.redundancy` and ``global_batch`` counts *distinct*
    covered samples.
    """
    from ..models import lm_loss     # deferred: models imports dist.sharding
    n = num_workers(mesh)
    assignment = assignment_from_config(amb, n)

    def step(params, opt_state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        if assignment is None:
            sw = seq_weights_from_b(b, gb, n)
            gbatch = jnp.sum(jnp.minimum(b, per))
        else:
            sw2, bw = epoch_weights(b, n, per, assignment)
            sw, gbatch = sw2.reshape(gb), bw.sum()

        def loss_fn(p):
            total, m = lm_loss(p, cfg, batch, sw)
            return total, m

        (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_state = opt.apply(grads, opt_state, params)
        metrics = {"loss": m["loss"], "aux": m["aux"], "ntok": m["ntok"],
                   "global_batch": gbatch}
        return new_params, new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Decentralized gossip train step (per-worker dual replicas)
# ---------------------------------------------------------------------------

def _prox_leaf(z_leaf, w0_leaf, beta_t, radius: Optional[float]):
    """Paper eq.-7 prox with h(w) = ||w - w0||^2 (f32 math, w0 dtype out)."""
    w0f = w0_leaf.astype(jnp.float32)
    w = w0f - z_leaf / (2.0 * beta_t)
    if radius is not None:
        delta = w - w0f
        nrm = jnp.linalg.norm(delta.reshape(-1))
        w = w0f + delta * jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return w.astype(w0_leaf.dtype)


def _local_grads(cfg, state, batch, sw, beta_t, radius, n, per):
    """vmapped per-worker masked gradients at each worker's own primal.

    ``sw``: (n, per) per-sequence weights — the 0/1 eq.-3 mask, or the
    fractional ``1/copies`` decode weights under coded redundancy
    (:func:`repro.dist.redundancy.epoch_weights`).  Returns (grads tree
    of (n, *param), losses (n,)).
    """
    from ..models import lm_loss     # deferred: models imports dist.sharding
    local = jax.tree.map(
        lambda x: x.reshape((n, per) + x.shape[1:]), batch)

    def local_grad(z_i, batch_i, sw_i):
        p_i = jax.tree.map(
            lambda w0l, zl: _prox_leaf(zl, w0l, beta_t, radius),
            state["w0"], z_i)

        def loss_fn(p):
            total, m = lm_loss(p, cfg, batch_i, sw_i)
            return total, m["loss"]

        (_, loss_i), g_i = jax.value_and_grad(loss_fn, has_aux=True)(p_i)
        return g_i, loss_i

    return jax.vmap(local_grad)(state["z"], local, sw)


def grad_noise_stats(grads, bw: Array) -> dict:
    """Cheap minibatch gradient-noise signals from per-worker gradients.

    ``grads``: tree of (n, *param) per-worker mean gradients; ``bw``: the
    (n,) effective per-worker sample counts (0 for masked workers, whose
    weight then vanishes).  Returns two scalars for
    :mod:`repro.control.telemetry`:

      * ``grad_sq_norm`` — ``||gbar||^2`` of the eq.-6 b-weighted mean
        gradient (biased up by ``tr(Sigma)/B``; telemetry corrects);
      * ``grad_var`` — the b-weighted between-worker dispersion
        ``sum_i (b_i/B) ||g_i - gbar||^2``, expectation
        ``tr(Sigma) (n-1)/B`` — a noise estimate that costs two scalar
        reductions, no extra backward pass.
    """
    w = bw / jnp.maximum(bw.sum(), 1.0)
    sq = jnp.float32(0.0)
    var = jnp.float32(0.0)
    for g in jax.tree.leaves(grads):
        flat = g.astype(jnp.float32).reshape(g.shape[0], -1)
        gbar = jnp.tensordot(w, flat, axes=(0, 0))
        sq = sq + jnp.sum(gbar * gbar)
        var = var + jnp.sum(w[:, None] * (flat - gbar) ** 2)
    return {"grad_sq_norm": sq, "grad_var": var}


def _init_gossip_state(params, mesh, n, waxes):
    """Per-worker dual replicas sharded along the worker axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    zshard = NamedSharding(mesh, P(waxes if n > 1 else None))

    def zeros(p):
        return jax.device_put(jnp.zeros((n,) + p.shape, jnp.float32),
                              zshard)

    return {"z": jax.tree.map(zeros, params),
            "w0": params,            # prox anchor w(1), original dtypes
            "t": jnp.zeros((), jnp.int32)}


def make_gossip_train_step(cfg, mesh, amb: AMBConfig):
    """Returns (init_state, step) for the decentralized AMB protocol.

    State: ``z`` — per-worker dual replicas, each leaf (n_workers, *param);
    ``w0`` — the shared init (prox anchor, paper eq. 2); ``t`` — epoch
    count.  step(state, batch, b) -> (state, metrics).  The consensus
    phase is whatever :class:`ConsensusStrategy` ``amb`` names (exact
    average, ring/torus gossip, quantized gossip).
    """
    n = num_workers(mesh)
    waxes = worker_axes(mesh)
    beta, radius = amb.beta, amb.radius
    strategy = strategy_from_config(amb, mesh)
    assignment = assignment_from_config(amb, n)
    qkey = jax.random.PRNGKey(amb.seed)

    def init_state(params):
        return _init_gossip_state(params, mesh, n, waxes)

    def step(state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        t = state["t"]
        beta_t = beta(t.astype(jnp.float32) + 1.0)   # beta used for w(t)
        sw, bw = epoch_weights(b, n, per, assignment)
        grads, losses = _local_grads(cfg, state, batch, sw, beta_t, radius,
                                     n, per)

        msg = pack_messages(state["z"], grads, n * bw, n)
        out = strategy.combine(msg, key=jax.random.fold_in(qkey, t))
        z_new = unpack_duals(out, state["z"], n)

        bsum = jnp.maximum(bw.sum(), 1.0)
        metrics = {"loss": jnp.sum(bw * losses) / bsum,
                   "global_batch": bw.sum(),
                   "beta": beta(t.astype(jnp.float32) + 2.0)}
        if amb.noise_stats:
            metrics.update(grad_noise_stats(grads, bw))
        return {"z": z_new, "w0": state["w0"], "t": t + 1}, metrics

    return init_state, step


def gossip_primal(state, amb: AMBConfig):
    """Node-averaged primal w̄(t) from a gossip-step state (checkpointing /
    eval): the same prox the train step applies, on the worker-mean dual.

    Under an elastic ``amb.active`` mask only the active workers' dual
    replicas are averaged — a departed worker's replica is frozen at its
    leave-time value (identity gossip row) and would otherwise bias the
    checkpoint away from the active set's consensus iterate.
    """
    t = state["t"].astype(jnp.float32)
    beta_t = amb.beta(t + 1.0)
    if amb.active is None:
        zbar = lambda z: z.mean(0)
    else:
        w = np.asarray(amb.active, np.float32)
        w = jnp.asarray(w / w.sum())

        def zbar(z):
            return jnp.tensordot(w, z, axes=(0, 0))

    return jax.tree.map(
        lambda w0, z: _prox_leaf(zbar(z), w0, beta_t, amb.radius),
        state["w0"], state["z"])
