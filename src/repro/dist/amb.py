"""Distributed AMB train steps on real device meshes (paper §3 -> SPMD).

Two implementations of the paper's epoch update, sharing the variable-
minibatch masking (eq. 3) and the eq.-6 weighted normalisation:

  * :func:`make_train_step` — *exact consensus* (eps = 0, the master/worker
    limit): one global weighted-loss backward pass.  The per-sequence 0/1
    weights from ``b_i(t)`` make its gradient exactly
    ``sum_i b_i g_i / sum_i b_i`` — the r -> infinity limit of gossip —
    and the update is any :class:`repro.optim.Optimizer` (dual averaging
    for the paper's protocol, AdamW/SGD baselines).

  * :func:`make_gossip_train_step` — *decentralized consensus* (Lemma 1
    regime): every worker keeps its own dual replica ``z_i``, computes its
    local masked gradient at its own primal ``w_i = prox(z_i)``, and runs
    ``r`` synchronous rounds of ring-Metropolis gossip on the messages
    ``n b_i (z_i + g_i)`` with the scalar ``n b_i`` alongside, so the
    normaliser b(t) is itself agreed by consensus — the same numerics as
    :func:`repro.core.consensus.gossip`, but laid out along the mesh worker
    axes with the K-way weighted combine fused by
    :mod:`repro.kernels.gossip_combine` on TPU.

Workers are the product of the non-"model" mesh axes, so a multi-pod
("pod", "data", "model") mesh gossips jointly across pod x data.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import consensus as cns
from ..core.dual_averaging import BetaSchedule
from ..kernels import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AMBConfig:
    """Static AMB step configuration (consensus + dual-averaging knobs)."""

    consensus: str = "exact"          # "exact" | "gossip"
    gossip_rounds: int = 5            # r (gossip path)
    graph: str = "ring"               # worker communication graph
    lazy: float = 0.5                 # lazy-Metropolis mixing (PSD P)
    beta: BetaSchedule = BetaSchedule()   # gossip-path dual averaging
    radius: Optional[float] = None


# ---------------------------------------------------------------------------
# Workers and variable-minibatch masking
# ---------------------------------------------------------------------------

def worker_axes(mesh) -> tuple:
    """Mesh axes that enumerate AMB workers (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_workers(mesh) -> int:
    """Workers = product of the non-"model" axis extents (pod x data)."""
    return int(np.prod([int(mesh.shape[a]) for a in worker_axes(mesh)],
                       dtype=np.int64)) if worker_axes(mesh) else 1


def seq_weights_from_b(b: Array, global_batch: int, n_workers: int) -> Array:
    """Per-sequence 0/1 inclusion weights from per-worker counts b_i(t).

    The global batch is laid out in ``n_workers`` contiguous blocks of
    ``global_batch // n_workers`` sequences; worker i's first ``b_i`` slots
    are included (paper eq. 3 with static shapes).  Returns (global_batch,)
    float32.
    """
    if global_batch % n_workers:
        raise ValueError(f"global_batch {global_batch} not divisible by "
                         f"{n_workers} workers")
    per = global_batch // n_workers
    idx = jnp.arange(global_batch)
    return ((idx % per) < b[idx // per]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Ring gossip along the worker dim (dim 0)
# ---------------------------------------------------------------------------

def ring_p(n: int, lazy: float = 0.5) -> np.ndarray:
    """Lazy-Metropolis ring weights (the worker-axis P; circulant)."""
    if n < 2:
        return np.ones((1, 1))
    return cns.metropolis_weights(cns.ring_graph(n), lazy=lazy)


def _circulant_taps(p: np.ndarray):
    """(offsets, weights) such that (P @ m)[i] = sum_k w_k m[(i - o_k) % n].

    Valid for circulant P (any ring).  Offset o corresponds to column
    j = (-o) % n of row 0.
    """
    n = p.shape[0]
    offsets, weights = [], []
    for j in range(n):
        if p[0, j] != 0.0:
            offsets.append((-j) % n)
            weights.append(float(p[0, j]))
    return tuple(offsets), np.asarray(weights, np.float32)


def ring_gossip(flat: Array, rounds: int, lazy: float = 0.5) -> Array:
    """``rounds`` rounds of ring-Metropolis gossip over dim 0 of (n, D).

    Numerically equivalent to ``consensus.gossip(flat, ring_p(n), rounds)``;
    each round is one K-way weighted combine of the rolled neighbor stacks
    (K = 3: self + two ring neighbors), fused by the Pallas
    ``gossip_combine`` kernel on TPU.  ``jnp.roll`` over a worker-sharded
    dim lowers to a collective-permute under SPMD.
    """
    n = flat.shape[0]
    if n < 2 or rounds < 1:
        return flat.astype(jnp.float32)
    offsets, weights = _circulant_taps(ring_p(n, lazy))
    w = jnp.asarray(weights)

    def one_round(_, m):
        stacked = jnp.stack([jnp.roll(m, o, axis=0) for o in offsets])
        out = kops.gossip_combine(stacked.reshape(len(offsets), -1), w)
        return out.reshape(m.shape)

    return jax.lax.fori_loop(0, rounds, one_round, flat.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Exact-consensus train step (eps = 0)
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt, mesh, amb: AMBConfig = AMBConfig()):
    """step(params, opt_state, batch, b) -> (params, opt_state, metrics).

    ``batch`` is the global batch (leading dim sharded over the worker
    axes); ``b`` the (n_workers,) per-worker minibatch sizes for this
    epoch.  The weighted loss's gradient equals the paper's eq.-6 global
    gradient, and ``opt`` applies the update (dual averaging: z += g,
    w = prox(z, beta)).
    """
    from ..models import lm_loss     # deferred: models imports dist.sharding
    n = num_workers(mesh)

    def step(params, opt_state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        sw = seq_weights_from_b(b, gb, n)

        def loss_fn(p):
            total, m = lm_loss(p, cfg, batch, sw)
            return total, m

        (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_state = opt.apply(grads, opt_state, params)
        metrics = {"loss": m["loss"], "aux": m["aux"], "ntok": m["ntok"],
                   "global_batch": jnp.sum(jnp.minimum(b, per))}
        return new_params, new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Decentralized gossip train step (per-worker dual replicas)
# ---------------------------------------------------------------------------

def _prox_leaf(z_leaf, w0_leaf, beta_t, radius: Optional[float]):
    """Paper eq.-7 prox with h(w) = ||w - w0||^2 (f32 math, w0 dtype out)."""
    w0f = w0_leaf.astype(jnp.float32)
    w = w0f - z_leaf / (2.0 * beta_t)
    if radius is not None:
        delta = w - w0f
        nrm = jnp.linalg.norm(delta.reshape(-1))
        w = w0f + delta * jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return w.astype(w0_leaf.dtype)


def make_gossip_train_step(cfg, mesh, amb: AMBConfig):
    """Returns (init_state, step) for the decentralized AMB protocol.

    State: ``z`` — per-worker dual replicas, each leaf (n_workers, *param);
    ``w0`` — the shared init (prox anchor, paper eq. 2); ``t`` — epoch
    count.  step(state, batch, b) -> (state, metrics).
    """
    from ..models import lm_loss     # deferred: models imports dist.sharding
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = num_workers(mesh)
    waxes = worker_axes(mesh)
    beta, radius = amb.beta, amb.radius
    rounds = amb.gossip_rounds
    if amb.graph != "ring":
        raise NotImplementedError("mesh gossip supports graph='ring'")

    def init_state(params):
        zshard = NamedSharding(mesh, P(waxes if n > 1 else None))

        def zeros(p):
            return jax.device_put(jnp.zeros((n,) + p.shape, jnp.float32),
                                  zshard)

        return {"z": jax.tree.map(zeros, params),
                "w0": params,        # prox anchor w(1), original dtypes
                "t": jnp.zeros((), jnp.int32)}

    def step(state, batch, b):
        gb = jax.tree.leaves(batch)[0].shape[0]
        per = gb // n
        t = state["t"]
        beta_t = beta(t.astype(jnp.float32) + 1.0)   # beta used for w(t)
        sw = seq_weights_from_b(b, gb, n).reshape(n, per)
        local = jax.tree.map(
            lambda x: x.reshape((n, per) + x.shape[1:]), batch)

        def local_grad(z_i, batch_i, sw_i):
            p_i = jax.tree.map(
                lambda w0l, zl: _prox_leaf(zl, w0l, beta_t, radius),
                state["w0"], z_i)

            def loss_fn(p):
                total, m = lm_loss(p, cfg, batch_i, sw_i)
                return total, m["loss"]

            (_, loss_i), g_i = jax.value_and_grad(
                loss_fn, has_aux=True)(p_i)
            return g_i, loss_i

        grads, losses = jax.vmap(local_grad)(state["z"], local, sw)

        # Messages n*b_i*(z_i + g_i) with the scalar n*b_i alongside, so the
        # eq.-6 normaliser is agreed by the same consensus (engine parity).
        bw = jnp.minimum(b, per).astype(jnp.float32)
        nb = (n * bw)
        leaves, treedef = jax.tree.flatten(state["z"])
        gleaves = jax.tree.leaves(grads)
        sizes = [int(np.prod(l.shape[1:], dtype=np.int64)) for l in leaves]
        msg = jnp.concatenate(
            [(nb.reshape((n,) + (1,) * (z.ndim - 1))
              * (z + g.astype(jnp.float32))).reshape(n, -1)
             for z, g in zip(leaves, gleaves)] + [nb.reshape(n, 1)], axis=1)

        out = ring_gossip(msg, rounds, amb.lazy) if n > 1 else msg
        # A worker whose gossip neighborhood processed no samples (scalar
        # ~ 0, e.g. a straggler-wiped epoch) keeps its dual unchanged —
        # matching the exact path, where a zero gradient leaves z alone.
        denom = jnp.maximum(out[:, -1:], 1e-12)
        zcat = jnp.concatenate([z.reshape(n, -1) for z in leaves], axis=1)
        zflat = jnp.where(out[:, -1:] > 1e-6, out[:, :-1] / denom, zcat)
        splits = np.cumsum(sizes)[:-1].tolist()
        z_new = jax.tree.unflatten(treedef, [
            part.reshape((n,) + l.shape[1:])
            for part, l in zip(jnp.split(zflat, splits, axis=1), leaves)])

        bsum = jnp.maximum(bw.sum(), 1.0)
        metrics = {"loss": jnp.sum(bw * losses) / bsum,
                   "global_batch": bw.sum(),
                   "beta": beta(t.astype(jnp.float32) + 2.0)}
        return {"z": z_new, "w0": state["w0"], "t": t + 1}, metrics

    return init_state, step


def gossip_primal(state, amb: AMBConfig):
    """Node-averaged primal w̄(t) from a gossip-step state (checkpointing /
    eval): the same prox the train step applies, on the worker-mean dual."""
    t = state["t"].astype(jnp.float32)
    beta_t = amb.beta(t + 1.0)
    return jax.tree.map(
        lambda w0, z: _prox_leaf(z.mean(0), w0, beta_t, amb.radius),
        state["w0"], state["z"])
