"""Pluggable consensus strategies for the mesh AMB stack (paper §3).

The consensus phase of the paper's epoch update is an operator on the
per-worker message stack: ``(n, D) -> (n, D)``.  The train steps in
:mod:`repro.dist.amb` and :mod:`repro.dist.pipeline` are written against
the :class:`ConsensusStrategy` interface and stay agnostic to *how* the
workers agree:

  * :class:`ExactConsensus` — the r -> infinity / master-worker limit
    (eps = 0): every worker ends up holding the global mean.  On a mesh
    this lowers to one all-reduce over the worker axes.
  * :class:`GossipConsensus` — r synchronous rounds of Metropolis gossip
    over any :func:`repro.core.consensus.build_graph` topology.  For
    group-circulant graphs (ring over Z_n, torus over Z_rows x Z_cols —
    the TPU ICI shapes) each round decomposes into K neighbor taps:
    rolls of the worker dim (collective-permutes under SPMD) plus one
    fused K-way weighted combine
    (:func:`repro.kernels.gossip_combine.gossip_combine_pallas`).
    Non-decomposable graphs (star, Erdos-Renyi, the paper's Fig. 2 graph)
    fall back to the dense ``P @ m`` of :func:`repro.core.consensus.gossip`.
  * :class:`QuantizedGossipConsensus` — the same taps, but each round's
    wire message is the CHOCO-style stochastically-quantized *delta*
    against a public replica, exactly the numerics of
    :func:`repro.core.extensions.gossip_quantized` (8/4-bit), with the
    quantize and dequantize+combine halves fused by the Pallas kernels in
    :mod:`repro.kernels.gossip_combine`.  The uint8 level planes (2/byte
    at 4-bit) are what crosses the ICI — (32/bits)x more rounds per T_c
    byte budget.

:func:`make_strategy` builds the right strategy from an
:class:`repro.dist.amb.AMBConfig` plus the mesh (the torus shape defaults
to the physical worker-axis extents).

Elastic membership (worker churn) has two regimes.  Ring/torus fleets
**relayout**: the survivors are re-enumerated onto a smaller ring/torus
whose operator is circulant again, so every round stays on the
collective-permute + fused-combine fast path — including the uint8
quantized wire planes (:class:`SurvivorTaps`).  Non-circulant graphs
(and ``relayout=False``) fall back to the dense induced-subgraph
operator of :func:`masked_metropolis`.  A single survivor degenerates
to the identity; an all-inactive mask is rejected.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import consensus as cns
from ..kernels import ops as kops

Array = jax.Array


# ---------------------------------------------------------------------------
# Group-circulant tap decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Taps:
    """``(P @ m)[i] = sum_k weights[k] * m[i + offsets[k]]`` over Z_shape.

    ``shape`` is the cyclic-group factorization of the worker index —
    ``(n,)`` for a ring, ``(rows, cols)`` for a torus.  Implemented as
    ``roll(m, -offset)`` per tap, which lowers to a collective-permute
    when the rolled dims are mesh-sharded.
    """

    offsets: tuple            # tuple of int tuples, one per tap
    weights: np.ndarray       # (K,) float32, self tap first
    shape: tuple              # cyclic-group shape, prod(shape) == n

    @property
    def k(self) -> int:
        return len(self.offsets)

    def take(self, x: Array, i: int) -> Array:
        """The i-th tap's neighbor view: ``out[r] = x[r + offsets[i]]``."""
        return roll_by_offset(x, self, self.offsets[i])


def group_taps(p: np.ndarray, shape: Sequence[int]) -> Optional[Taps]:
    """Decompose a group-circulant P into neighbor taps, or None.

    Valid iff ``P[i, j]`` depends only on the elementwise difference
    ``coord(j) - coord(i)`` mod ``shape`` (true for Metropolis weights on
    any vertex-transitive graph laid out over the cyclic group — ring,
    torus).  Validated by reconstructing P; returns None on mismatch so
    callers can fall back to the dense operator.
    """
    shape = tuple(int(s) for s in shape)
    n = p.shape[0]
    if int(np.prod(shape)) != n:
        return None
    offsets, weights = [], []
    for j in range(n):
        if p[0, j] != 0.0:
            offsets.append(np.unravel_index(j, shape))
            weights.append(float(p[0, j]))
    # self tap first (offset all-zeros), if present
    order = sorted(range(len(offsets)),
                   key=lambda i: (any(offsets[i]), offsets[i]))
    offsets = [offsets[i] for i in order]
    weights = [weights[i] for i in order]
    # validate: rebuild P from the taps
    rebuilt = np.zeros_like(p)
    coords = np.stack(np.unravel_index(np.arange(n), shape), axis=1)
    for off, w in zip(offsets, weights):
        dest = np.ravel_multi_index(
            tuple((coords[:, a] + off[a]) % shape[a]
                  for a in range(len(shape))), shape)
        rebuilt[np.arange(n), dest] += w
    if not np.allclose(rebuilt, p, atol=1e-12):
        return None
    return Taps(offsets=tuple(tuple(int(o) for o in off) for off in offsets),
                weights=np.asarray(weights, np.float32), shape=shape)


def masked_metropolis(adj: np.ndarray, active, lazy: float) -> np.ndarray:
    """Metropolis weights on the subgraph induced by the ``active`` mask.

    Elastic membership (worker join/leave): edges touching an inactive
    worker are removed and the Metropolis degrees re-derived on the
    induced subgraph, so active workers re-weight their remaining
    neighbors instead of waiting on a departed one.  Inactive workers
    become identity rows (they neither send nor relay; their stale dual
    survives untouched until they rejoin).  The active subgraph must stay
    connected — a partitioned fleet cannot reach consensus.

    This is the *dense* membership operator — ``P @ m`` per round.  It
    remains the fallback for non-circulant graphs (and the
    ``relayout=False`` A/B baseline); ring/torus fleets normally take
    :func:`survivor_taps` instead, which reconnects the survivors on a
    fresh ring/torus (so non-adjacent failures never partition it) and
    keeps the collective-permute fast path.
    """
    active = np.asarray(active, dtype=bool)
    adj = np.asarray(adj, dtype=bool) & active[None, :] & active[:, None]
    n_act = int(active.sum())
    if n_act >= 2 and not cns.is_connected(adj[np.ix_(active, active)]):
        raise ValueError("active worker subgraph is disconnected; "
                         "consensus cannot mix across the partition")
    return cns.metropolis_weights(adj, lazy=lazy)


def roll_by_offset(x: Array, taps: Taps, off) -> Array:
    """``out[i] = x[i + off]`` over the taps' cyclic group (one tap)."""
    full = x.reshape(taps.shape + x.shape[1:])
    axes = tuple(range(len(taps.shape)))
    return jnp.roll(full, tuple(-o for o in off), axis=axes).reshape(x.shape)


# ---------------------------------------------------------------------------
# Survivor relayout (elastic membership phase 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SurvivorTaps:
    """Tap decomposition of a *survivor-relayout* gossip operator.

    :func:`masked_metropolis` keeps the survivors on the physical graph's
    induced subgraph — which loses the group-circulant structure the roll
    taps need (and can even disconnect), forcing the dense ``P @ m``
    slow path whenever a worker is down.  Relayout instead re-enumerates
    the ``n_act`` survivors (by physical index) as ranks of a *fresh*
    ring / torus over ``Z_{n_act}``: the small operator is circulant
    again, so it tap-decomposes, and each survivor-rank offset becomes a
    small set of **physical** worker-axis rolls — rank r's tap-``i``
    neighbor sits ``delta = p_{r+o_i} - p_r (mod n)`` physical slots
    away, and survivors with equal ``delta`` share one roll.  ``take``
    therefore lowers to at most a handful of collective-permutes plus
    masked selects per tap, keeping churned fleets on the fast path (and
    on the uint8 wire planes: the rolls work on any dtype).

    Fields: ``offsets`` / ``weights`` / ``shape`` describe the small
    operator on survivor ranks (self tap first, ``prod(shape) ==
    n_act``); ``hops[i]`` is the physical realisation of tap i — a tuple
    of ``(delta, mask)`` pairs with disjoint (n,) bool masks selecting
    which physical rows read from ``delta`` slots ahead; ``active`` is
    the membership mask, ``n`` the full fleet size.  Inactive rows are
    identity rows (their stale dual survives until rejoin) — the
    strategies re-select them after the combine.
    """

    offsets: tuple            # survivor-rank offsets, self tap first
    weights: np.ndarray       # (K,) float32
    shape: tuple              # survivor group shape, prod == n_act
    hops: tuple               # per tap: ((delta, (n,) bool mask), ...)
    active: np.ndarray        # (n,) bool membership mask
    n: int                    # full fleet size

    @property
    def k(self) -> int:
        return len(self.offsets)

    def take(self, x: Array, i: int) -> Array:
        """Tap i's neighbor view on the *physical* axis.

        Row ``p`` of the result holds ``x[p + delta_p]`` for active rows
        (``delta_p`` from the rank relayout) and 0 for inactive rows —
        the hop masks are disjoint, so the masked rolls just sum.  Works
        for any dtype (fp32 payloads and uint8 wire planes alike).
        """
        if i == 0:
            return x
        out = None
        for delta, mask in self.hops[i]:
            m = jnp.asarray(mask).reshape((self.n,) + (1,) * (x.ndim - 1))
            rolled = jnp.roll(x, -delta, axis=0) if delta else x
            part = jnp.where(m, rolled, jnp.zeros((), x.dtype))
            out = part if out is None else out + part
        return out if out is not None else jnp.zeros_like(x)

    def dense(self) -> np.ndarray:
        """The (n, n) operator this realises (tests / spectral checks):
        the relayout P on the survivor block, identity rows elsewhere."""
        p = np.zeros((self.n, self.n))
        idx = np.arange(self.n)
        for w, hop in zip(self.weights, self.hops):
            for delta, mask in hop:
                rows = idx[mask]
                p[rows, (rows + delta) % self.n] += float(w)
        inact = ~np.asarray(self.active, bool)
        p[inact, idx[inact]] = 1.0
        return p


def survivor_taps(active, graph: str = "ring", lazy: float = 0.5
                  ) -> Optional[SurvivorTaps]:
    """Relayout the active set onto a fresh ring/torus; None if the tap
    form is unavailable (< 2 survivors, or a non-circulant relayout).

    The survivor count picks the relayout shape: a ring over the
    ``n_act`` survivors, or — when the original graph was a torus and
    ``n_act`` factors into a true 2-D torus — the most-square
    ``rows x cols`` torus.  The construction is validated by rebuilding
    the dense operator and comparing against the embedded small P.
    """
    act = np.asarray(active, dtype=bool)
    n = act.size
    surv = np.nonzero(act)[0]
    n_act = surv.size
    if n_act < 2:
        return None
    if graph == "torus":
        rows, cols = _default_torus(n_act)
        if rows >= 2 and cols >= 2:
            shape, adj = (rows, cols), cns.torus_graph(rows, cols)
        else:                       # prime / tiny survivor counts: ring
            shape, adj = (n_act,), cns.ring_graph(n_act)
    elif graph == "ring":
        shape, adj = (n_act,), cns.ring_graph(n_act)
    else:
        return None
    p_small = cns.metropolis_weights(adj, lazy=lazy)
    taps_small = group_taps(p_small, shape)
    if taps_small is None:
        return None
    coords = np.stack(np.unravel_index(np.arange(n_act), shape), axis=1)
    hops = []
    for off in taps_small.offsets:
        src_rank = np.ravel_multi_index(
            tuple((coords[:, a] + off[a]) % shape[a]
                  for a in range(len(shape))), shape)
        delta = (surv[src_rank] - surv) % n       # physical roll per rank
        tap_hops = []
        for d in sorted({int(x) for x in delta}):
            mask = np.zeros(n, dtype=bool)
            mask[surv[delta == d]] = True
            tap_hops.append((d, mask))
        hops.append(tuple(tap_hops))
    taps = SurvivorTaps(offsets=taps_small.offsets,
                        weights=taps_small.weights, shape=shape,
                        hops=tuple(hops), active=act.copy(), n=n)
    # validate: the physical realisation must equal the embedded small P
    emb = np.eye(n)
    emb[np.ix_(surv, surv)] = p_small
    if not np.allclose(taps.dense(), emb, atol=1e-12):
        return None
    return taps


def _mask_rows(out: Array, orig: Array, active) -> Array:
    """Re-select inactive workers' original rows (identity rows) after a
    survivor-tap combine; no-op for full-fleet operators."""
    if active is None:
        return out
    mask = jnp.asarray(np.asarray(active, bool)).reshape(
        (-1,) + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, orig)


def _roll_taps(m: Array, taps) -> Array:
    """Stack the neighbor views: (K, n, ...) from (n, ...)."""
    return jnp.stack([taps.take(m, i) for i in range(taps.k)])


# ---------------------------------------------------------------------------
# Strategy interface
# ---------------------------------------------------------------------------

class ConsensusStrategy:
    """Operator on the per-worker message stack: (n, D) -> (n, D).

    ``combine`` runs the whole consensus phase (all rounds).  ``key`` is
    only consumed by stochastic strategies (quantized gossip) and may be
    None otherwise.  ``wire_bytes_per_round`` is the per-worker payload a
    single round puts on the interconnect — what the multi-pod benchmarks
    report.
    """

    name: str = "base"

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        raise NotImplementedError

    def wire_bytes_per_round(self, d: int) -> int:
        raise NotImplementedError

    def __call__(self, msg: Array, key: Optional[Array] = None) -> Array:
        return self.combine(msg, key)


@dataclasses.dataclass(frozen=True)
class ExactConsensus(ConsensusStrategy):
    """eps = 0: every worker holds the global mean (one all-reduce)."""

    n: int
    name: str = dataclasses.field(default="exact", init=False)

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        return cns.exact_average(msg.astype(jnp.float32))

    def wire_bytes_per_round(self, d: int) -> int:
        return 4 * d          # fp32 all-reduce payload (ring: 2x in+out)


class _TapGossip(ConsensusStrategy):
    """Shared P/tap construction for the gossip strategies.

    Elastic membership: an ``active`` mask with >= 2 survivors on a
    ring/torus relays out via :func:`survivor_taps` (collective-permute
    fast path preserved; ``relayout=False`` forces the legacy dense
    :func:`masked_metropolis` operator for A/B benchmarking).  A single
    survivor degenerates to the identity (no permutes, no dense op);
    an all-inactive mask is rejected — there is no operator to build.
    """

    def __init__(self, n: int, rounds: int, graph: str = "ring",
                 lazy: float = 0.5, torus_shape: Optional[tuple] = None,
                 active: Optional[Sequence[bool]] = None,
                 relayout: bool = True):
        self.n = int(n)
        self.rounds = int(rounds)
        self.graph = graph
        self.lazy = float(lazy)
        self.relayout = bool(relayout)
        self.identity = False
        self.active = None if active is None or all(active) \
            else tuple(bool(a) for a in active)
        if n < 2:
            self.p, self.taps = np.ones((1, 1)), None
            return
        if graph == "torus":
            rows, cols = torus_shape or _default_torus(n)
            if rows * cols != n:
                raise ValueError(f"torus {rows}x{cols} != {n} workers")
            adj = cns.torus_graph(rows, cols)
            shape = (rows, cols)
        else:
            adj = cns.build_graph(graph, n)
            shape = (n,)
        if self.active is not None:
            if len(self.active) != n:
                raise ValueError(f"active mask has {len(self.active)} "
                                 f"entries for {n} workers")
            n_act = sum(self.active)
            if n_act == 0:
                raise ValueError("at least one worker must stay active; "
                                 "an all-inactive fleet has no consensus "
                                 "operator")
            if n_act == 1:
                # single survivor: consensus degenerates to the identity
                # — no permutes, no dense operator, dual untouched
                self.identity = True
                self.p, self.taps = np.eye(n), None
                return
            if self.relayout:
                self.taps = survivor_taps(self.active, graph, lazy)
                if self.taps is not None:
                    self.p = self.taps.dense()
                    return
            # dense fallback: masked Metropolis on the induced subgraph
            # (non-circulant graphs, or relayout explicitly disabled)
            self.p = masked_metropolis(adj, self.active, lazy)
            self.taps = None
        else:
            self.p = cns.metropolis_weights(adj, lazy=lazy)
            self.taps = group_taps(self.p, shape)

    def wire_bytes_per_round(self, d: int) -> int:
        k = self.taps.k if self.taps is not None else self.n
        return 4 * d * (k - 1)     # fp32 message to each neighbor


class GossipConsensus(_TapGossip):
    """r rounds of Metropolis gossip; tap-decomposed where possible.

    Per round (group-circulant graphs): one roll per neighbor tap — a
    collective-permute under SPMD — and one fused K-way weighted combine
    on TPU.  Numerically identical to
    ``repro.core.consensus.gossip(m, P, rounds)``.
    """

    name = "gossip"

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        m = msg.astype(jnp.float32)
        if self.n < 2 or self.rounds < 1 or self.identity:
            return m
        if self.taps is None:        # dense fallback (non-circulant graph)
            return cns.gossip(m, jnp.asarray(self.p, jnp.float32),
                              self.rounds)
        w = jnp.asarray(self.taps.weights)

        def one_round(_, cur):
            stacked = _roll_taps(cur, self.taps)
            out = kops.gossip_combine(
                stacked.reshape(self.taps.k, -1), w)
            return out.reshape(cur.shape)

        out = jax.lax.fori_loop(0, self.rounds, one_round, m)
        # survivor relayout: inactive workers keep their rows (identity);
        # no active row ever reads an inactive one, so one final select
        # equals the dense masked operator's per-round identity rows
        return _mask_rows(out, m, getattr(self.taps, "active", None))


class QuantizedGossipConsensus(_TapGossip):
    """Delta-compressed gossip: ``repro.core.extensions.gossip_quantized``
    laid out along the mesh worker axes.

    Every worker keeps a public replica ``h`` of its own value and one
    running replica per neighbor tap; each round it stochastically
    quantizes ``m - h`` onto a per-worker uniform grid (``bits`` bits),
    sends only the uint8 level plane plus two grid scalars, and combines
    ``m <- P_ii m + sum_k P_ik hnbr_k`` — the self term stays exact, the
    delta magnitude (hence injected noise) decays with consensus.  Given
    the same per-round uniform draws this reproduces ``gossip_quantized``
    exactly; the rounds budget is scaled by the caller ((32/bits)x per
    T_c).  Requires a PRNG ``key``.
    """

    name = "gossip_q"

    def __init__(self, n: int, rounds: int, bits: int = 8,
                 graph: str = "ring", lazy: float = 0.5,
                 torus_shape: Optional[tuple] = None,
                 active: Optional[Sequence[bool]] = None,
                 relayout: bool = True):
        super().__init__(n, rounds, graph, lazy, torus_shape, active,
                         relayout)
        if bits not in (4, 8):
            raise ValueError("bits must be 4 or 8 (uint8 wire container)")
        self.bits = int(bits)
        self.name = f"gossip_q{bits}"

    def wire_bytes_per_round(self, d: int) -> int:
        # uint8 level container; 4-bit packs two levels per byte (the
        # per-tap payload actually put on the wire by _pack/_unpack), plus
        # the two f32 grid scalars per neighbor message.
        k = self.taps.k if self.taps is not None else self.n
        per_msg = (-(-d // 2) if self.bits == 4 else d) + 8
        return per_msg * (k - 1)

    def _pack(self, lvl: Array) -> Array:
        """4-bit wire format: two levels per byte (lossless)."""
        if self.bits != 4:
            return lvl
        n, d = lvl.shape
        if d % 2:
            lvl = jnp.pad(lvl, ((0, 0), (0, 1)))
        return lvl[:, ::2] | (lvl[:, 1::2] << 4)

    def _unpack(self, packed: Array, d: int) -> Array:
        if self.bits != 4:
            return packed
        both = jnp.stack([packed & 0xF, packed >> 4], axis=-1)
        return both.reshape(both.shape[0], -1)[:, :d]

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        if key is None:
            raise ValueError("QuantizedGossipConsensus needs a PRNG key")
        m = msg.astype(jnp.float32)
        if self.n < 2 or self.rounds < 1 or self.identity:
            return m
        # the fused path needs the self tap first (w[0] multiplies m)
        if self.taps is None or any(self.taps.offsets[0]):
            from ..core.extensions import gossip_quantized
            return gossip_quantized(m, jnp.asarray(self.p, jnp.float32),
                                    self.rounds, self.bits, key)
        taps = self.taps
        levels = float(2 ** self.bits - 1)
        d = m.shape[1]
        w = jnp.asarray(taps.weights)
        km1 = taps.k - 1

        def one_round(k_round, carry):
            cur, h, hnbr = carry
            # -- send half: stochastic-quantize the delta, update replica
            diff = cur - h
            lo = diff.min(axis=-1, keepdims=True)
            hi = diff.max(axis=-1, keepdims=True)
            scale = jnp.maximum(hi - lo, 1e-12) / levels
            # partitionable threefry: the rounding plane is drawn shard-
            # locally; the sequential impl's u32 resharding costs more
            # wire bytes per round than the u8 level planes themselves
            # (must match core.extensions.quantize_unbiased's draws)
            with jax.threefry_partitionable(True):
                rnd = jax.random.uniform(jax.random.fold_in(key, k_round),
                                         cur.shape)
            lvl, h_new = kops.stochastic_quantize(cur, h, rnd, lo, scale,
                                                  levels)
            # -- the wire: rolled (nibble-packed) level planes + scalars.
            # The barriers pin the collective-permute to the uint8 plane:
            # without them XLA hoists the u8->f32 dequant (and the 4-bit
            # unpack) across the roll, putting fp32 on the interconnect
            # and defeating the (32/bits)x byte saving (see the
            # multipod_2x16x16 section of BENCH_dist.json).
            wire = jax.lax.optimization_barrier(self._pack(lvl))
            lvl_r = jnp.stack([
                self._unpack(
                    jax.lax.optimization_barrier(taps.take(wire, j)), d)
                for j in range(1, taps.k)])
            lo_r = jnp.stack([taps.take(lo, j) for j in range(1, taps.k)])
            sc_r = jnp.stack([taps.take(scale, j)
                              for j in range(1, taps.k)])
            # -- receive half: fused dequantize + replica update + combine
            out, hnbr_new = kops.quantized_combine(
                cur, hnbr, lvl_r, lo_r, sc_r, w)
            return out, h_new, hnbr_new

        h0 = jnp.zeros_like(m)
        hnbr0 = jnp.zeros((km1,) + m.shape, jnp.float32)
        out, _, _ = jax.lax.fori_loop(0, self.rounds, one_round,
                                      (m, h0, hnbr0))
        # survivor relayout: restore inactive workers' original rows —
        # their replicas only ever accumulate the taps' zero fill, and
        # no active row reads them, so the select is exact
        return _mask_rows(out, m, getattr(self.taps, "active", None))


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def _default_torus(n: int) -> tuple:
    rows = int(np.sqrt(n))
    while n % rows:
        rows -= 1
    return rows, n // rows


def torus_shape_for_mesh(mesh) -> Optional[tuple]:
    """The physical worker-axis extents as the torus (rows, cols).

    A ("pod", "data", "model") mesh gossips over pod x data, so the
    natural torus is (pod_extent, data_extent) — each roll then permutes
    along exactly one physical mesh axis.  Single-worker-axis meshes fall
    back to the most-square factorization.
    """
    waxes = [a for a in mesh.axis_names if a != "model"]
    if len(waxes) == 2:
        return int(mesh.shape[waxes[0]]), int(mesh.shape[waxes[1]])
    return None


CONSENSUS_CHOICES = ("exact", "gossip", "gossip_q8", "gossip_q4")


def make_strategy(name: str, n: int, *, rounds: int = 5,
                  graph: str = "ring", lazy: float = 0.5,
                  torus_shape: Optional[tuple] = None,
                  active: Optional[Sequence[bool]] = None,
                  relayout: bool = True) -> ConsensusStrategy:
    """Build a strategy from the AMBConfig vocabulary.

    ``name`` in {"exact", "gossip", "gossip_q8", "gossip_q4"}.  Quantized
    strategies get (32/bits)x the rounds — same T_c byte budget.  An
    ``active`` worker mask (elastic membership) rebuilds the gossip
    operator: ring/torus fleets relayout the survivors onto a smaller
    ring/torus whose taps stay on the collective-permute fast path
    (:func:`survivor_taps`; ``relayout=False`` forces the legacy dense
    :func:`masked_metropolis` operator), non-circulant graphs take the
    dense induced-subgraph operator.  Exact consensus needs no rebuild —
    a departed worker's zero-weighted message (b_i = 0) already drops
    out of the eq.-6 average.
    """
    if name == "exact":
        return ExactConsensus(n)
    if name == "gossip":
        return GossipConsensus(n, rounds, graph, lazy, torus_shape, active,
                               relayout)
    if name in ("gossip_q8", "gossip_q4"):
        bits = int(name[-1])
        return QuantizedGossipConsensus(n, rounds * 32 // bits, bits,
                                        graph, lazy, torus_shape, active,
                                        relayout)
    raise ValueError(f"unknown consensus strategy {name!r}; "
                     f"choose from {CONSENSUS_CHOICES}")
