"""Pluggable consensus strategies for the mesh AMB stack (paper §3).

The consensus phase of the paper's epoch update is an operator on the
per-worker message stack: ``(n, D) -> (n, D)``.  The train steps in
:mod:`repro.dist.amb` and :mod:`repro.dist.pipeline` are written against
the :class:`ConsensusStrategy` interface and stay agnostic to *how* the
workers agree:

  * :class:`ExactConsensus` — the r -> infinity / master-worker limit
    (eps = 0): every worker ends up holding the global mean.  On a mesh
    this lowers to one all-reduce over the worker axes.
  * :class:`GossipConsensus` — r synchronous rounds of Metropolis gossip
    over any :func:`repro.core.consensus.build_graph` topology.  For
    group-circulant graphs (ring over Z_n, torus over Z_rows x Z_cols —
    the TPU ICI shapes) each round decomposes into K neighbor taps:
    rolls of the worker dim (collective-permutes under SPMD) plus one
    fused K-way weighted combine
    (:func:`repro.kernels.gossip_combine.gossip_combine_pallas`).
    Non-decomposable graphs (star, Erdos-Renyi, the paper's Fig. 2 graph)
    fall back to the dense ``P @ m`` of :func:`repro.core.consensus.gossip`.
  * :class:`QuantizedGossipConsensus` — the same taps, but each round's
    wire message is the CHOCO-style stochastically-quantized *delta*
    against a public replica, exactly the numerics of
    :func:`repro.core.extensions.gossip_quantized` (8/4-bit), with the
    quantize and dequantize+combine halves fused by the Pallas kernels in
    :mod:`repro.kernels.gossip_combine`.  The uint8 level planes (2/byte
    at 4-bit) are what crosses the ICI — (32/bits)x more rounds per T_c
    byte budget.

:func:`make_strategy` builds the right strategy from an
:class:`repro.dist.amb.AMBConfig` plus the mesh (the torus shape defaults
to the physical worker-axis extents).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import consensus as cns
from ..kernels import ops as kops

Array = jax.Array


# ---------------------------------------------------------------------------
# Group-circulant tap decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Taps:
    """``(P @ m)[i] = sum_k weights[k] * m[i + offsets[k]]`` over Z_shape.

    ``shape`` is the cyclic-group factorization of the worker index —
    ``(n,)`` for a ring, ``(rows, cols)`` for a torus.  Implemented as
    ``roll(m, -offset)`` per tap, which lowers to a collective-permute
    when the rolled dims are mesh-sharded.
    """

    offsets: tuple            # tuple of int tuples, one per tap
    weights: np.ndarray       # (K,) float32, self tap first
    shape: tuple              # cyclic-group shape, prod(shape) == n

    @property
    def k(self) -> int:
        return len(self.offsets)


def group_taps(p: np.ndarray, shape: Sequence[int]) -> Optional[Taps]:
    """Decompose a group-circulant P into neighbor taps, or None.

    Valid iff ``P[i, j]`` depends only on the elementwise difference
    ``coord(j) - coord(i)`` mod ``shape`` (true for Metropolis weights on
    any vertex-transitive graph laid out over the cyclic group — ring,
    torus).  Validated by reconstructing P; returns None on mismatch so
    callers can fall back to the dense operator.
    """
    shape = tuple(int(s) for s in shape)
    n = p.shape[0]
    if int(np.prod(shape)) != n:
        return None
    offsets, weights = [], []
    for j in range(n):
        if p[0, j] != 0.0:
            offsets.append(np.unravel_index(j, shape))
            weights.append(float(p[0, j]))
    # self tap first (offset all-zeros), if present
    order = sorted(range(len(offsets)),
                   key=lambda i: (any(offsets[i]), offsets[i]))
    offsets = [offsets[i] for i in order]
    weights = [weights[i] for i in order]
    # validate: rebuild P from the taps
    rebuilt = np.zeros_like(p)
    coords = np.stack(np.unravel_index(np.arange(n), shape), axis=1)
    for off, w in zip(offsets, weights):
        dest = np.ravel_multi_index(
            tuple((coords[:, a] + off[a]) % shape[a]
                  for a in range(len(shape))), shape)
        rebuilt[np.arange(n), dest] += w
    if not np.allclose(rebuilt, p, atol=1e-12):
        return None
    return Taps(offsets=tuple(tuple(int(o) for o in off) for off in offsets),
                weights=np.asarray(weights, np.float32), shape=shape)


def masked_metropolis(adj: np.ndarray, active, lazy: float) -> np.ndarray:
    """Metropolis weights on the subgraph induced by the ``active`` mask.

    Elastic membership (worker join/leave): edges touching an inactive
    worker are removed and the Metropolis degrees re-derived on the
    induced subgraph, so active workers re-weight their remaining
    neighbors instead of waiting on a departed one.  Inactive workers
    become identity rows (they neither send nor relay; their stale dual
    survives untouched until they rejoin).  The active subgraph must stay
    connected — a partitioned fleet cannot reach consensus.
    """
    active = np.asarray(active, dtype=bool)
    adj = np.asarray(adj, dtype=bool) & active[None, :] & active[:, None]
    n_act = int(active.sum())
    if n_act >= 2 and not cns.is_connected(adj[np.ix_(active, active)]):
        raise ValueError("active worker subgraph is disconnected; "
                         "consensus cannot mix across the partition")
    return cns.metropolis_weights(adj, lazy=lazy)


def roll_by_offset(x: Array, taps: Taps, off) -> Array:
    """``out[i] = x[i + off]`` over the taps' cyclic group (one tap)."""
    full = x.reshape(taps.shape + x.shape[1:])
    axes = tuple(range(len(taps.shape)))
    return jnp.roll(full, tuple(-o for o in off), axis=axes).reshape(x.shape)


def _roll_taps(m: Array, taps: Taps) -> Array:
    """Stack the rolled neighbor views: (K, n, ...) from (n, ...)."""
    return jnp.stack([roll_by_offset(m, taps, off) for off in taps.offsets])


# ---------------------------------------------------------------------------
# Strategy interface
# ---------------------------------------------------------------------------

class ConsensusStrategy:
    """Operator on the per-worker message stack: (n, D) -> (n, D).

    ``combine`` runs the whole consensus phase (all rounds).  ``key`` is
    only consumed by stochastic strategies (quantized gossip) and may be
    None otherwise.  ``wire_bytes_per_round`` is the per-worker payload a
    single round puts on the interconnect — what the multi-pod benchmarks
    report.
    """

    name: str = "base"

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        raise NotImplementedError

    def wire_bytes_per_round(self, d: int) -> int:
        raise NotImplementedError

    def __call__(self, msg: Array, key: Optional[Array] = None) -> Array:
        return self.combine(msg, key)


@dataclasses.dataclass(frozen=True)
class ExactConsensus(ConsensusStrategy):
    """eps = 0: every worker holds the global mean (one all-reduce)."""

    n: int
    name: str = dataclasses.field(default="exact", init=False)

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        return cns.exact_average(msg.astype(jnp.float32))

    def wire_bytes_per_round(self, d: int) -> int:
        return 4 * d          # fp32 all-reduce payload (ring: 2x in+out)


class _TapGossip(ConsensusStrategy):
    """Shared P/tap construction for the gossip strategies."""

    def __init__(self, n: int, rounds: int, graph: str = "ring",
                 lazy: float = 0.5, torus_shape: Optional[tuple] = None,
                 active: Optional[Sequence[bool]] = None):
        self.n = int(n)
        self.rounds = int(rounds)
        self.graph = graph
        self.lazy = float(lazy)
        self.active = None if active is None or all(active) \
            else tuple(bool(a) for a in active)
        if n < 2:
            self.p, self.taps = np.ones((1, 1)), None
            return
        if graph == "torus":
            rows, cols = torus_shape or _default_torus(n)
            if rows * cols != n:
                raise ValueError(f"torus {rows}x{cols} != {n} workers")
            adj = cns.torus_graph(rows, cols)
            shape = (rows, cols)
        else:
            adj = cns.build_graph(graph, n)
            shape = (n,)
        if self.active is not None:
            if len(self.active) != n:
                raise ValueError(f"active mask has {len(self.active)} "
                                 f"entries for {n} workers")
            # masked P is not group-circulant: run the dense operator
            self.p = masked_metropolis(adj, self.active, lazy)
            self.taps = None
        else:
            self.p = cns.metropolis_weights(adj, lazy=lazy)
            self.taps = group_taps(self.p, shape)

    def wire_bytes_per_round(self, d: int) -> int:
        k = self.taps.k if self.taps is not None else self.n
        return 4 * d * (k - 1)     # fp32 message to each neighbor


class GossipConsensus(_TapGossip):
    """r rounds of Metropolis gossip; tap-decomposed where possible.

    Per round (group-circulant graphs): one roll per neighbor tap — a
    collective-permute under SPMD — and one fused K-way weighted combine
    on TPU.  Numerically identical to
    ``repro.core.consensus.gossip(m, P, rounds)``.
    """

    name = "gossip"

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        m = msg.astype(jnp.float32)
        if self.n < 2 or self.rounds < 1:
            return m
        if self.taps is None:        # dense fallback (non-circulant graph)
            return cns.gossip(m, jnp.asarray(self.p, jnp.float32),
                              self.rounds)
        w = jnp.asarray(self.taps.weights)

        def one_round(_, cur):
            stacked = _roll_taps(cur, self.taps)
            out = kops.gossip_combine(
                stacked.reshape(self.taps.k, -1), w)
            return out.reshape(cur.shape)

        return jax.lax.fori_loop(0, self.rounds, one_round, m)


class QuantizedGossipConsensus(_TapGossip):
    """Delta-compressed gossip: ``repro.core.extensions.gossip_quantized``
    laid out along the mesh worker axes.

    Every worker keeps a public replica ``h`` of its own value and one
    running replica per neighbor tap; each round it stochastically
    quantizes ``m - h`` onto a per-worker uniform grid (``bits`` bits),
    sends only the uint8 level plane plus two grid scalars, and combines
    ``m <- P_ii m + sum_k P_ik hnbr_k`` — the self term stays exact, the
    delta magnitude (hence injected noise) decays with consensus.  Given
    the same per-round uniform draws this reproduces ``gossip_quantized``
    exactly; the rounds budget is scaled by the caller ((32/bits)x per
    T_c).  Requires a PRNG ``key``.
    """

    name = "gossip_q"

    def __init__(self, n: int, rounds: int, bits: int = 8,
                 graph: str = "ring", lazy: float = 0.5,
                 torus_shape: Optional[tuple] = None,
                 active: Optional[Sequence[bool]] = None):
        super().__init__(n, rounds, graph, lazy, torus_shape, active)
        if bits not in (4, 8):
            raise ValueError("bits must be 4 or 8 (uint8 wire container)")
        self.bits = int(bits)
        self.name = f"gossip_q{bits}"

    def wire_bytes_per_round(self, d: int) -> int:
        # uint8 level container; 4-bit packs two levels per byte (the
        # per-tap payload actually put on the wire by _pack/_unpack), plus
        # the two f32 grid scalars per neighbor message.
        k = self.taps.k if self.taps is not None else self.n
        per_msg = (-(-d // 2) if self.bits == 4 else d) + 8
        return per_msg * (k - 1)

    def _pack(self, lvl: Array) -> Array:
        """4-bit wire format: two levels per byte (lossless)."""
        if self.bits != 4:
            return lvl
        n, d = lvl.shape
        if d % 2:
            lvl = jnp.pad(lvl, ((0, 0), (0, 1)))
        return lvl[:, ::2] | (lvl[:, 1::2] << 4)

    def _unpack(self, packed: Array, d: int) -> Array:
        if self.bits != 4:
            return packed
        both = jnp.stack([packed & 0xF, packed >> 4], axis=-1)
        return both.reshape(both.shape[0], -1)[:, :d]

    def combine(self, msg: Array, key: Optional[Array] = None) -> Array:
        if key is None:
            raise ValueError("QuantizedGossipConsensus needs a PRNG key")
        m = msg.astype(jnp.float32)
        if self.n < 2 or self.rounds < 1:
            return m
        # the fused path needs the self tap first (w[0] multiplies m)
        if self.taps is None or any(self.taps.offsets[0]):
            from ..core.extensions import gossip_quantized
            return gossip_quantized(m, jnp.asarray(self.p, jnp.float32),
                                    self.rounds, self.bits, key)
        taps = self.taps
        levels = float(2 ** self.bits - 1)
        d = m.shape[1]
        w = jnp.asarray(taps.weights)
        km1 = taps.k - 1
        nbr_offsets = taps.offsets[1:]

        def one_round(k_round, carry):
            cur, h, hnbr = carry
            # -- send half: stochastic-quantize the delta, update replica
            diff = cur - h
            lo = diff.min(axis=-1, keepdims=True)
            hi = diff.max(axis=-1, keepdims=True)
            scale = jnp.maximum(hi - lo, 1e-12) / levels
            # partitionable threefry: the rounding plane is drawn shard-
            # locally; the sequential impl's u32 resharding costs more
            # wire bytes per round than the u8 level planes themselves
            # (must match core.extensions.quantize_unbiased's draws)
            with jax.threefry_partitionable(True):
                rnd = jax.random.uniform(jax.random.fold_in(key, k_round),
                                         cur.shape)
            lvl, h_new = kops.stochastic_quantize(cur, h, rnd, lo, scale,
                                                  levels)
            # -- the wire: rolled (nibble-packed) level planes + scalars.
            # The barriers pin the collective-permute to the uint8 plane:
            # without them XLA hoists the u8->f32 dequant (and the 4-bit
            # unpack) across the roll, putting fp32 on the interconnect
            # and defeating the (32/bits)x byte saving (see the
            # multipod_2x16x16 section of BENCH_dist.json).
            wire = jax.lax.optimization_barrier(self._pack(lvl))
            lvl_r = jnp.stack([
                self._unpack(
                    jax.lax.optimization_barrier(
                        roll_by_offset(wire, taps, o)), d)
                for o in nbr_offsets])
            lo_r = jnp.stack([roll_by_offset(lo, taps, o)
                              for o in nbr_offsets])
            sc_r = jnp.stack([roll_by_offset(scale, taps, o)
                              for o in nbr_offsets])
            # -- receive half: fused dequantize + replica update + combine
            out, hnbr_new = kops.quantized_combine(
                cur, hnbr, lvl_r, lo_r, sc_r, w)
            return out, h_new, hnbr_new

        h0 = jnp.zeros_like(m)
        hnbr0 = jnp.zeros((km1,) + m.shape, jnp.float32)
        out, _, _ = jax.lax.fori_loop(0, self.rounds, one_round,
                                      (m, h0, hnbr0))
        return out


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def _default_torus(n: int) -> tuple:
    rows = int(np.sqrt(n))
    while n % rows:
        rows -= 1
    return rows, n // rows


def torus_shape_for_mesh(mesh) -> Optional[tuple]:
    """The physical worker-axis extents as the torus (rows, cols).

    A ("pod", "data", "model") mesh gossips over pod x data, so the
    natural torus is (pod_extent, data_extent) — each roll then permutes
    along exactly one physical mesh axis.  Single-worker-axis meshes fall
    back to the most-square factorization.
    """
    waxes = [a for a in mesh.axis_names if a != "model"]
    if len(waxes) == 2:
        return int(mesh.shape[waxes[0]]), int(mesh.shape[waxes[1]])
    return None


CONSENSUS_CHOICES = ("exact", "gossip", "gossip_q8", "gossip_q4")


def make_strategy(name: str, n: int, *, rounds: int = 5,
                  graph: str = "ring", lazy: float = 0.5,
                  torus_shape: Optional[tuple] = None,
                  active: Optional[Sequence[bool]] = None
                  ) -> ConsensusStrategy:
    """Build a strategy from the AMBConfig vocabulary.

    ``name`` in {"exact", "gossip", "gossip_q8", "gossip_q4"}.  Quantized
    strategies get (32/bits)x the rounds — same T_c byte budget.  An
    ``active`` worker mask (elastic membership) rebuilds the gossip
    operator on the induced subgraph via :func:`masked_metropolis`;
    exact consensus needs no rebuild — a departed worker's zero-weighted
    message (b_i = 0) already drops out of the eq.-6 average.
    """
    if name == "exact":
        return ExactConsensus(n)
    if name == "gossip":
        return GossipConsensus(n, rounds, graph, lazy, torus_shape, active)
    if name in ("gossip_q8", "gossip_q4"):
        bits = int(name[-1])
        return QuantizedGossipConsensus(n, rounds * 32 // bits, bits,
                                        graph, lazy, torus_shape, active)
    raise ValueError(f"unknown consensus strategy {name!r}; "
                     f"choose from {CONSENSUS_CHOICES}")
