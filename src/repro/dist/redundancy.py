"""Coded gradient redundancy: overlapping data shards + decode-on-settle.

AMB's variable-minibatch mechanism (paper eq. 3) already tolerates
workers that are merely *slow* — a straggler's b_i(t) shrinks toward 0
and its sequence weights vanish from the eq.-6 average.  But when a
worker *vanishes* (fail-stop, churn), every sample assigned to it is
simply lost: the surviving workers average over a smaller — still
unbiased but noisier — sample, and a correlated outage can wipe a whole
region of the data stream for many epochs.

The gradient-coding line of work (Tandon et al.; Karakus et al.,
arXiv:1803.05397; Li et al., arXiv:1710.09990 — see PAPERS.md) fixes
this by *assigning data redundantly*: each distinct sample is placed on
``rho`` workers, laid out so any surviving subset that covers a sample
can reconstruct the uncoded full-gradient estimate exactly.  This module
implements the fractional-repetition / rotated-overlapping-shard scheme
over the AMB worker axes:

  * **Placement** (:class:`CodedAssignment`): the ``n`` workers are
    partitioned into ``n / rho`` groups of ``rho``; every member of
    group g holds the *same* distinct data block (the group's shard of
    the stream), but **rotated** by ``member * per / rho`` slots.
    Member m's first-b_i samples therefore start at a different point
    of the block, so partial minibatches of distinct members cover
    *complementary* slots before they overlap (Li et al.'s overlapping
    batches), and a single surviving member with b_i = per covers the
    whole block (fractional repetition).
  * **Decode** (:meth:`CodedAssignment.decode_weights` /
    :func:`epoch_weights`): instead of a separate decoding matrix, the
    reconstruction rides the sequence-weight mechanism the step already
    has — each worker's included sample is weighted ``1 / copies`` where
    ``copies`` counts how many group members' minibatches cover that
    distinct slot this epoch.  Every covered distinct slot then
    contributes total weight exactly 1 across the fleet, so the eq.-6
    b-weighted mean gradient equals the plain mean over the distinct
    covered samples — an unbiased full-gradient estimate from any
    surviving (or straggling) k-of-n subset, with no decode step: the
    weights flow through ``lm_loss`` (which supports fractional
    sequence weights) and the agreed ``sum w`` normaliser column of
    :func:`repro.dist.amb.pack_messages`.

``rho = 1`` (or ``assignment=None``) reproduces the uncoded eq.-3 path
**bit-exactly** — same ops, same 0/1 weights — so golden-parity tests
and default sessions are untouched.  Nothing here imports
:mod:`repro.dist.amb` (that module builds on this one).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CodedAssignment:
    """Fractional-repetition placement of data blocks over ``n`` workers.

    ``rho`` is the replication factor: workers ``g*rho .. (g+1)*rho - 1``
    form group g and all hold group g's distinct data block, member m
    rotated by ``m * per / rho`` slots.  ``rho = 1`` is the uncoded
    layout (group = worker, no rotation).
    """

    n: int
    rho: int = 1

    def __post_init__(self):
        if self.rho < 1:
            raise ValueError(f"redundancy must be >= 1, got {self.rho}")
        if self.n % self.rho:
            raise ValueError(f"redundancy {self.rho} must divide the "
                             f"{self.n} workers (fractional-repetition "
                             f"groups)")

    @property
    def groups(self) -> int:
        return self.n // self.rho

    def group(self, i: int) -> int:
        return i // self.rho

    def data_nodes(self) -> np.ndarray:
        """Stream node index per worker: group members share a node."""
        return np.arange(self.n) // self.rho

    def shifts(self, per: int) -> np.ndarray:
        """Rotation offset per worker (slots): member m of any group
        starts its minibatch at block slot ``m * per / rho``."""
        member = np.arange(self.n) % self.rho
        return (member * per) // self.rho

    def decode_weights(self, b: Array, per: int):
        """Per-sequence decode weights from this epoch's b_i(t).

        ``b``: (n,) per-worker minibatch sizes (0 for failed / masked /
        fully-straggled workers).  Returns ``(sw, bw_eff)``:

          * ``sw`` — (n, per) float32; worker i's local slot s gets
            ``1 / copies`` if ``s < b_i`` (where ``copies`` counts the
            group members covering the same *distinct* block slot this
            epoch), else 0.  Every covered distinct slot sums to weight
            1 across its group.
          * ``bw_eff`` — (n,) float32 effective sample counts
            ``sum_s sw[i, s]`` (the eq.-6 / pack_messages weights; their
            fleet sum equals the number of distinct samples covered).

        In-graph (``b`` may be traced); all index maps are static.
        """
        n, rho = self.n, self.rho
        bw = jnp.minimum(b, per).astype(jnp.int32)
        if rho <= 1:
            # uncoded: the exact eq.-3 ops of seq_weights_from_b
            idx = jnp.arange(n * per)
            sw = ((idx % per) < b[idx // per]).astype(jnp.float32)
            return sw.reshape(n, per), bw.astype(jnp.float32)
        shift = self.shifts(per)                        # (n,) static
        # worker j covers distinct block slot u iff its local position
        # of u — (u - shift_j) mod per — lies inside its minibatch b_j
        local_of_block = (np.arange(per)[None, :] - shift[:, None]) % per
        covered = jnp.asarray(local_of_block) < bw[:, None]     # (n, per)
        copies = covered.reshape(self.groups, rho, per).sum(1)  # (G, per)
        # gather each worker's copy-counts at its own (rotated) slots
        block_of_local = (np.arange(per)[None, :] + shift[:, None]) % per
        cw = jnp.take_along_axis(jnp.repeat(copies, rho, axis=0),
                                 jnp.asarray(block_of_local), axis=1)
        sw = jnp.where(jnp.arange(per)[None, :] < bw[:, None],
                       1.0 / jnp.maximum(cw, 1).astype(jnp.float32), 0.0)
        return sw, sw.sum(axis=1)


def epoch_weights(b: Array, n: int, per: int,
                  assignment: Optional[CodedAssignment] = None):
    """(sw (n, per), bw_eff (n,)) for one epoch — coded or uncoded.

    The single entry point the train steps use: ``assignment=None`` (or
    ``rho = 1``) is the bit-exact uncoded eq.-3 path; a coded assignment
    returns the ``1/copies`` decode weights (see
    :meth:`CodedAssignment.decode_weights`).
    """
    if assignment is None:
        assignment = CodedAssignment(n, 1)
    if assignment.n != n:
        raise ValueError(f"assignment covers {assignment.n} workers, "
                         f"step has {n}")
    return assignment.decode_weights(b, per)
