"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST set the placeholder device count before any jax import (jax locks the
device count on first init) — hence the first two lines.
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from ..api.protocol import build_protocol                    # noqa: E402
from ..configs import ARCH_NAMES, SHAPES, get_config        # noqa: E402
from ..dist import use_sharding                              # noqa: E402
from ..dist.amb import AMBConfig                             # noqa: E402
from ..dist.params import tree_shardings                     # noqa: E402
from ..models import decode_step, prefill                    # noqa: E402
from ..optim import DualAveragingOpt                         # noqa: E402
from . import specs as S                                     # noqa: E402
from .mesh import make_production_mesh                       # noqa: E402

# v5e constants for §Roofline
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_COLLECTIVE_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\]\S*\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\b")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}

# per-chip traffic multipliers (ring algorithms); shapes in the partitioned
# module are per-device.
_TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-op result bytes for every collective in the (partitioned) HLO.

    Each op also carries a ``by_dtype`` byte breakdown — how the quantized
    wire shows up as u8 (vs fp32 / RNG-u32) in the collective-permutes.
    """
    out = {k: {"count": 0, "bytes": 0.0, "by_dtype": {}}
           for k in _TRAFFIC_FACTOR}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        out[op]["count"] += 1
        out[op]["bytes"] += size * nbytes
        out[op]["by_dtype"][dt] = out[op]["by_dtype"].get(dt, 0) \
            + size * nbytes
    out["traffic_bytes"] = sum(
        v["bytes"] * _TRAFFIC_FACTOR[k]
        for k, v in out.items() if k in _TRAFFIC_FACTOR)
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) training; 2*N*D for fwd-only."""
    n_params = cfg.param_count()
    if cfg.is_moe:
        d, ff, e, k = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.experts_per_token
        moe_total = cfg.num_layers * e * 3 * d * ff
        moe_active = cfg.num_layers * k * 3 * d * ff
        n_params = n_params - moe_total + moe_active
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params * tokens


def _lower_combo(cfg, shape, mesh):
    """Lower the right step for (cfg, shape) on mesh. Returns Lowered."""
    params_sds = S.abstract_params(cfg)
    # Decode serves one token per step: FSDP ("data"-sharded) weights would
    # be re-all-gathered on every matvec (measured: 5 weight gathers/layer
    # on rwkv6 long_500k — §Perf hillclimb 2).  Serving replicates weights
    # over "data" (throughput axis) and keeps tensor parallel on "model".
    # NOTE (§Perf hillclimb 2, iteration 2, REFUTED): replicate_tmix=True
    # for ssm decode cut the collective term 23x (no head-boundary state
    # gathers) but raised the memory term 5.2x (full tmix weights read per
    # token) — the ICI->HBM trade loses: the binding term went 1.8 ms ->
    # 8.6 ms.  Keep tensor-parallel tmix.
    # MoE keeps FSDP at decode too: expert weights dominate its bytes and
    # replicating them over "data" costs ~16x HBM reads per token, which
    # outweighs the dense-layer weight-gather saving (§Perf sweep).
    fsdp = "data" if (shape.kind == "train" or cfg.is_moe) else None
    pspecs = tree_shardings(params_sds, mesh, fsdp_axis=fsdp)
    as_in = lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh)
    params_in = jax.tree.map(as_in, params_sds, pspecs)

    if shape.kind == "train":
        from jax.sharding import NamedSharding, PartitionSpec as P
        opt = DualAveragingOpt()
        proto = build_protocol(cfg, mesh, AMBConfig(), optimizer=opt)
        # TrainState structure comes from the protocol itself; only the
        # shardings are assigned here (params keep the fsdp choice above)
        state_sds = jax.eval_shape(proto.init, params_sds)
        state_specs = {"params": pspecs,
                       "opt": tree_shardings(state_sds["opt"], mesh),
                       "t": NamedSharding(mesh, P())}
        state_in = jax.tree.map(as_in, state_sds, state_specs)
        batch = S.train_input_specs(cfg, shape, mesh)
        b = S.worker_batch_spec(mesh)
        return jax.jit(proto.step).lower(state_in, batch, b)
    if shape.kind == "prefill":
        batch = S.prefill_input_specs(cfg, shape, mesh)
        return jax.jit(lambda p, bt: prefill(p, cfg, bt)).lower(
            params_in, batch)
    # decode
    state_sds = S.abstract_decode_state(cfg, shape)
    sspecs = S.decode_state_specs(state_sds, mesh, shape.global_batch)
    state_in = jax.tree.map(
        lambda sds, sp: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=jax.sharding.NamedSharding(mesh, sp)),
        state_sds, sspecs)
    tok = S.decode_token_spec(shape, mesh)
    return jax.jit(lambda p, st, t: decode_step(p, cfg, st, t)).lower(
        params_in, state_in, tok)


def _costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": parse_collectives(compiled.as_text())}


def _depth_variant(cfg, layers: int, seq_len: int):
    """Cost-measurement config: reduced depth (encoder scaled in lockstep).

    Chunk sizes stay production-representative (so HBM traffic matches the
    real flash/SSD programs) but are raised at very long sequences to bound
    the unrolled block count — every block body appears explicitly in HLO
    under ``unrolled_loops()``, which is what makes cost_analysis exact."""
    kw = {"num_layers": layers}
    if seq_len > 8192:
        kw["q_chunk"] = kw["kv_chunk"] = 4096
        kw["ssm_chunk"] = 2048
    if cfg.encoder_layers:
        kw["encoder_layers"] = max(
            1, round(cfg.encoder_layers * layers / cfg.num_layers))
    return dataclasses.replace(cfg, **kw)


def extrapolated_costs(cfg, shape, mesh) -> dict:
    """XLA cost_analysis counts while-loop (lax.scan) bodies ONCE, so the
    layer-stack contribution must be recovered by depth extrapolation:
    compile UNROLLED depth p and 2p (p = the repeating unit, attn_every for
    hybrids), then total(L) = c(p) + (L-p)/p * (c(2p) - c(p)).  Exact for
    homogeneous scanned stacks.
    """
    from ..models.common import unrolled_loops
    p = cfg.attn_every if (cfg.family == "hybrid" and cfg.attn_every) else 1
    with unrolled_loops():
        c1 = _costs(_lower_combo(
            _depth_variant(cfg, p, shape.seq_len), shape, mesh).compile())
        c2 = _costs(_lower_combo(
            _depth_variant(cfg, 2 * p, shape.seq_len), shape, mesh).compile())
    k = (cfg.num_layers - p) / p
    out = {
        "flops": c1["flops"] + k * (c2["flops"] - c1["flops"]),
        "bytes": c1["bytes"] + k * (c2["bytes"] - c1["bytes"]),
    }
    coll = {}
    for op in _TRAFFIC_FACTOR:
        b1 = c1["collectives"][op]["bytes"]
        b2 = c2["collectives"][op]["bytes"]
        n1 = c1["collectives"][op]["count"]
        n2 = c2["collectives"][op]["count"]
        coll[op] = {"bytes": b1 + k * (b2 - b1),
                    "count": round(n1 + k * (n2 - n1), 1)}
    coll["traffic_bytes"] = sum(
        coll[op]["bytes"] * _TRAFFIC_FACTOR[op] for op in _TRAFFIC_FACTOR)
    out["collectives"] = coll
    return out


def _mesh(multi_pod: bool):
    """Production mesh, or a reduced test mesh via REPRO_DRYRUN_MESH=d,m."""
    override = os.environ.get("REPRO_DRYRUN_MESH")
    if override:
        dims = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
            consensus: str = "exact") -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch, shape=shape_name)
    mesh = _mesh(multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "kind": shape.kind, "consensus": consensus}

    t0 = time.time()
    with use_sharding(mesh):
        lowered = _lower_combo(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        full = _costs(compiled)
        rec["hlo_flops_module"] = full["flops"]
        rec["hlo_bytes_module"] = full["bytes"]
        rec["collectives_module"] = full["collectives"]
        try:
            ma = compiled.memory_analysis()
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, f):
                    rec[f] = int(getattr(ma, f))
        except Exception as e:  # pragma: no cover
            rec["memory_analysis_error"] = str(e)

        # Depth extrapolation (2 extra unrolled compiles) feeds the
        # single-pod §Roofline table; the multi-pod pass only needs the
        # lower+compile proof + memory analysis, so skip it there.
        extr = {} if multi_pod else extrapolated_costs(cfg, shape, mesh)

    rec["hlo_flops"] = extr.get("flops", full["flops"])
    rec["hlo_bytes"] = extr.get("bytes", full["bytes"])
    rec["collectives"] = extr.get("collectives", full["collectives"])
    rec["depth_extrapolated"] = bool(extr)

    # ---- roofline terms (per chip; post-SPMD HLO is per-device) ----
    flops = rec["hlo_flops"]
    rec["model_flops"] = model_flops(cfg, shape)
    rec["compute_s_roofline"] = flops / PEAK_FLOPS
    rec["memory_s_roofline"] = rec["hlo_bytes"] / HBM_BW
    rec["collective_s_roofline"] = (
        rec["collectives"]["traffic_bytes"] / LINK_BW)
    terms = {"compute": rec["compute_s_roofline"],
             "memory": rec["memory_s_roofline"],
             "collective": rec["collective_s_roofline"]}
    rec["dominant_term"] = max(terms, key=terms.get)
    rec["useful_flops_frac"] = (
        rec["model_flops"] / (flops * chips) if flops else 0.0)

    outdir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh']}.json"
    (outdir / name).write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = outdir / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    print(f"[skip] {arch} {shape} {mesh_name}")
                    continue
                t0 = time.time()
                try:
                    rec = run_one(arch, shape, mp, outdir)
                    print(f"[ok]   {arch:22s} {shape:12s} {mesh_name:8s} "
                          f"flops={rec['hlo_flops']:.3e} "
                          f"dom={rec['dominant_term']:10s} "
                          f"({time.time()-t0:.0f}s)")
                except Exception as e:
                    failures.append((arch, shape, mesh_name, str(e)))
                    print(f"[FAIL] {arch} {shape} {mesh_name}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
