"""Batched serving driver: prefill a batch of prompts, then decode tokens.

The serving analogue of AMB's fixed-time contract: each decode *round* has a
fixed wall-clock budget; requests are grouped into a batch, every round emits
one token per active request (continuous batching over a fixed-shape slot
array).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from ..dist import use_sharding
from ..dist.params import tree_shardings
from ..models import decode_step, init_params, prefill
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.data, args.model)
    key = jax.random.PRNGKey(args.seed)

    with use_sharding(mesh):
        params = init_params(key, cfg)
        params = jax.tree.map(lambda p, sh: jax.device_put(p, sh), params,
                              tree_shardings(params, mesh))
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.input_mode == "embeds":
            batch = {"embeds": params["embed"][toks]}
        if cfg.family == "audio":
            batch["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.jdtype)

        prefill_fn = jax.jit(
            lambda p, b: prefill(p, cfg, b, extra_capacity=args.new_tokens))
        step_fn = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))

        t0 = time.time()
        logits, state = prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s "
              f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

        out_tokens = []
        tok = jnp.argmax(logits, axis=-1)
        t0 = time.time()
        for _ in range(args.new_tokens):
            out_tokens.append(tok)
            logits, state = step_fn(params, state, tok)
            tok = jnp.argmax(logits, axis=-1)
        tok.block_until_ready()
        t_dec = time.time() - t0
        print(f"decode: {args.new_tokens} rounds x {args.batch} reqs in "
              f"{t_dec:.2f}s ({args.new_tokens * args.batch / t_dec:.0f} tok/s)")
        gen = jnp.stack(out_tokens, axis=1)
        print("generated token ids (first request):",
              gen[0][:16].tolist(), "...")
    return gen


if __name__ == "__main__":
    main()
