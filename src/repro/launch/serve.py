"""Batched serving driver: one :class:`repro.api.AMBSession` for both
AMB fine-tuning and decode.

The serving analogue of AMB's fixed-time contract: each decode *round* has
a fixed wall-clock budget; requests are grouped into a batch, every round
emits one token per active request (continuous batching over a fixed-shape
slot array).

``--finetune N`` runs N batch-parallel AMB fine-tuning steps through the
session *before* decoding — the session owns the mesh, the sharded
parameters, the clock, the consensus strategy, and the prefetched data
plane (``session.run`` feeds per-worker LM-stream shards through a
background :class:`repro.data.Prefetcher`), and ``session.params``
hands the post-fine-tune primal straight to prefill/decode.  With
``--finetune 0`` (default) the session still does the mesh + param setup,
so decode-only serving shares the exact same initialization path as
training.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 --finetune 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
from ..dist import use_sharding
from ..models import decode_step, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--finetune", type=int, default=0, metavar="STEPS",
                    help="AMB fine-tuning steps to run through the "
                         "session before decoding (0 = decode only)")
    ap.add_argument("--finetune-seq-len", type=int, default=64)
    ap.add_argument("--finetune-batch-per-worker", type=int, default=2)
    from ..dist.consensus import CONSENSUS_CHOICES
    ap.add_argument("--consensus", default="exact",
                    choices=list(CONSENSUS_CHOICES),
                    help="consensus strategy for --finetune")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="JSONL path for per-epoch --finetune metrics "
                         "(written by the session's MetricsLogger)")
    args = ap.parse_args(argv)

    train = TrainSpec(arch=args.arch, smoke=args.smoke,
                      seq_len=args.finetune_seq_len,
                      batch_per_worker=args.finetune_batch_per_worker,
                      data=args.data, model=args.model, seed=args.seed)
    try:
        session = AMBSession(train, ClockSpec(),
                             ConsensusSpec(consensus=args.consensus),
                             metrics_path=args.metrics)
    except ValueError as e:
        raise SystemExit(str(e))
    cfg, mesh = session.cfg, session.mesh

    if args.finetune:
        t0 = time.time()

        def on_step(step, m):
            step = step - 1      # the 0-based epoch that just ran
            if step % 5 == 0 or step == args.finetune - 1:
                print(f"finetune {step:3d} loss {m['loss']:.4f} "
                      f"b(t)={m['global_batch']:.0f}")

        # prefetched data plane: the session's default per-worker
        # LM-stream shards, built + device-put ahead of the step
        session.run(args.finetune, on_step=on_step)
        session.flush()
        session.close()      # flush the metrics JSONL before decode
        print(f"finetune: {args.finetune} AMB steps in "
              f"{time.time() - t0:.2f}s")

    params = session.params      # the shared primal: fine-tuned or init
    with use_sharding(mesh):
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks}
        if cfg.input_mode == "embeds":
            batch = {"embeds": params["embed"][toks]}
        if cfg.family == "audio":
            batch["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.jdtype)

        prefill_fn = jax.jit(
            lambda p, b: prefill(p, cfg, b, extra_capacity=args.new_tokens))
        step_fn = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))

        t0 = time.time()
        logits, state = prefill_fn(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s "
              f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

        out_tokens = []
        tok = jnp.argmax(logits, axis=-1)
        t0 = time.time()
        for _ in range(args.new_tokens):
            out_tokens.append(tok)
            logits, state = step_fn(params, state, tok)
            tok = jnp.argmax(logits, axis=-1)
        tok.block_until_ready()
        t_dec = time.time() - t0
        print(f"decode: {args.new_tokens} rounds x {args.batch} reqs in "
              f"{t_dec:.2f}s ({args.new_tokens * args.batch / t_dec:.0f} tok/s)")
        gen = jnp.stack(out_tokens, axis=1)
        print("generated token ids (first request):",
              gen[0][:16].tolist(), "...")
    return gen


if __name__ == "__main__":
    main()
