"""Serving driver: thin CLI over :mod:`repro.serve`.

Continuous batching over a fixed slot array with background AMB
fine-tuning absorbed into the round budget — the serving analogue of
the paper's fixed-time contract (each round has a fixed wall-clock
budget; requests contribute whatever tokens fit; leftover budget goes
to training instead of idling).

``--requests N`` synthesizes a staggered workload (``--arrival-gap``
seconds between arrivals, prompt lengths jittered around
``--prompt-len``); ``--batch`` sets the slot count; ``--finetune N``
caps the background AMB epochs the scheduler may absorb.  The session
owns the mesh, sharded params, clock, consensus and data plane exactly
as in training; ``session.params`` hands the primal to the slot
engine.  SLO metrics (TTFT / TPOT / latency p50-p99, tokens/s) and
per-epoch train loss stream to ``--metrics`` as JSONL.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --requests 12 --prompt-len 64 --new-tokens 32 \
      --finetune 8 --round-budget 0.25
"""
from __future__ import annotations

import argparse
import json

from ..api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
from ..serve import (AdmissionPolicy, RequestQueue, SamplingSpec,
                     ServeMetrics, ServeScheduler, SlotEngine,
                     synthetic_requests)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (concurrent requests)")
    ap.add_argument("--requests", type=int, default=0, metavar="N",
                    help="requests to serve (0 = one per slot)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--arrival-gap", type=float, default=0.0, metavar="S",
                    help="seconds between staggered arrivals")
    ap.add_argument("--round-budget", type=float, default=0.25, metavar="S",
                    help="fixed time budget per decode round (the AMB "
                         "contract: budget fixed, work variable)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best tokens (0 = all)")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true",
                    help="force greedy decode (same as --temperature 0)")
    ap.add_argument("--finetune", type=int, default=0, metavar="STEPS",
                    help="cap on background AMB fine-tune epochs absorbed "
                         "into idle round budget (0 = serve only)")
    ap.add_argument("--finetune-seq-len", type=int, default=64)
    ap.add_argument("--finetune-batch-per-worker", type=int, default=2)
    from ..dist.consensus import CONSENSUS_CHOICES
    ap.add_argument("--consensus", default="exact",
                    choices=list(CONSENSUS_CHOICES),
                    help="consensus strategy for --finetune")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="JSONL path for SLO + fine-tune metrics")
    args = ap.parse_args(argv)

    train = TrainSpec(arch=args.arch, smoke=args.smoke,
                      seq_len=args.finetune_seq_len,
                      batch_per_worker=args.finetune_batch_per_worker,
                      data=args.data, model=args.model, seed=args.seed)
    try:
        session = AMBSession(train, ClockSpec(),
                             ConsensusSpec(consensus=args.consensus),
                             metrics_path=args.metrics)
    except ValueError as e:
        raise SystemExit(str(e))
    cfg, mesh = session.cfg, session.mesh

    temperature = 0.0 if args.greedy else args.temperature
    sampling = SamplingSpec(temperature=temperature, top_k=args.top_k,
                            seed=args.seed)
    jitter = min(args.prompt_len - 1, args.prompt_len // 4)
    cache_len = args.prompt_len + jitter + args.new_tokens
    n_req = args.requests or args.batch
    reqs = synthetic_requests(
        n_req, vocab_size=cfg.vocab_size, prompt_len=args.prompt_len,
        prompt_jitter=jitter, max_new_tokens=args.new_tokens,
        arrival_gap_s=args.arrival_gap, seed=args.seed + 1)
    queue = RequestQueue(AdmissionPolicy(cache_len=cache_len))
    for r in reqs:
        queue.push(r)

    try:
        engine = SlotEngine(session.params, cfg, slots=args.batch,
                            cache_len=cache_len, sampling=sampling,
                            mesh=mesh)
        sched = ServeScheduler(engine, queue,
                               round_budget_s=args.round_budget,
                               session=session if args.finetune else None,
                               train_epochs=args.finetune,
                               metrics=ServeMetrics(session.metrics))
        report = sched.run()
        session.flush()      # settle in-flight gossip (pipelined mode)
        print(json.dumps(report.summary, indent=2, sort_keys=True))
        if report.requests:
            r0 = min(report.requests, key=lambda r: r.rid)
            print(f"request {r0.rid} tokens:", r0.out_tokens[:16],
                  "..." if len(r0.out_tokens) > 16 else "")
        return report
    finally:
        session.close()      # idempotent; flushes SLO + train JSONL


if __name__ == "__main__":
    main()
