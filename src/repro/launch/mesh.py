"""Production meshes.

A function (not a module-level constant) so importing never touches jax
device state: the dry-run sets XLA_FLAGS for 512 host devices *before* any
jax import; smoke tests see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips).

    Axes: "data" = AMB workers (data parallel / FSDP), "model" =
    tensor/expert parallel inside a worker, "pod" = the cross-pod worker
    axis (consensus spans ("pod", "data") jointly).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, *, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    ndev = len(jax.devices())
    need = data * model * pod
    if ndev < need:
        raise RuntimeError(f"need {need} devices, have {ndev} "
                           f"(set --xla_force_host_platform_device_count)")
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
