"""AMB training driver: real steps on whatever devices exist.

Runs an LM (reduced or full config) under the AMB protocol: every step a
straggler clock converts the fixed budget T into per-worker minibatch
sizes b_i(t), and the train step consumes the masked batch with weighted
consensus + dual averaging.

On the mesh path the clock is **measured** by default: the per-gradient
time unit comes from an EMA of the real per-step wall-clock (the
straggler model only supplies the relative cross-worker heterogeneity),
so b_i(t) tracks the actual hardware rate instead of the simulated
constants — pass ``--sim-clock`` to restore the paper-evaluation
simulated clock.  Consensus is pluggable
(``--consensus {exact,gossip,gossip_q8,gossip_q4}``, ``--graph
{ring,torus}``) and ``--pipeline`` switches to the staleness-1 epoch
that overlaps each step's gossip with the next forward/backward.

Example (8 simulated devices, reduced qwen2, pipelined torus gossip):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --data 4 --model 2 --consensus gossip --graph torus \
      --pipeline
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import metrics as metrics_mod
from ..ckpt import save_checkpoint
from ..configs import get_config, smoke_config
from ..core.dual_averaging import BetaSchedule
from ..core.stragglers import ShiftedExponential, amb_batch_sizes, fmb_finish_times
from ..data import LMTokenStream, shard_batch
from ..dist import use_sharding
from ..dist.amb import (AMBConfig, gossip_primal, make_gossip_train_step,
                        make_train_step, num_workers)
from ..dist.consensus import CONSENSUS_CHOICES
from ..dist.params import tree_shardings
from ..dist.pipeline import make_pipelined_gossip_train_step
from ..models import init_params
from ..optim import make_optimizer
from .mesh import make_host_mesh


class MeasuredClock:
    """b_i(t) from real per-step wall-clock timings (mesh path default).

    The simulated straggler model keeps one job — supplying the *relative*
    per-worker heterogeneity (its per-gradient draws divided by its own
    mean) — while the absolute seconds-per-gradient unit is an EMA of the
    measured step time divided by the gradients that step consumed.  The
    Lemma-6 budget ``T = (1 + n/b) mu`` is re-derived from the measured
    unit each step, so the epoch deadline tracks the actual hardware rate
    (compile-time warmup, cache effects, CPU contention) instead of the
    model's constants.
    """

    def __init__(self, model, n: int, batch_per_worker: int,
                 ema: float = 0.7):
        self.model = model
        self.n = n
        self.bpw = batch_per_worker
        self.ema = ema
        # model-relative unit: mean seconds per gradient in model time
        self.model_unit = model.mean_batch_time() / model.b_ref
        self.sec_per_grad = None      # measured EMA; None until first step

    def update(self, step_seconds: float, global_b: float) -> None:
        obs = step_seconds / max(global_b, 1.0)
        self.sec_per_grad = (obs if self.sec_per_grad is None else
                             self.ema * self.sec_per_grad
                             + (1.0 - self.ema) * obs)

    def times(self, key) -> jax.Array:
        """(n, b_max) per-gradient times in *measured* seconds."""
        rel = self.model.per_gradient_times(key, self.n, self.bpw) \
            / self.model_unit                       # mean-1 heterogeneity
        unit = self.sec_per_grad if self.sec_per_grad is not None \
            else self.model_unit                    # pre-measurement boot
        return rel * unit

    def budget(self) -> float:
        """Lemma-6 T in measured seconds: (1 + n/b) * mu_measured."""
        unit = self.sec_per_grad if self.sec_per_grad is not None \
            else self.model_unit
        gb = self.n * self.bpw
        return (1.0 + self.n / gb) * unit * self.bpw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-per-worker", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--optimizer", default="dual_averaging",
                    choices=["dual_averaging", "adamw", "sgd"])
    ap.add_argument("--mode", default="amb", choices=["amb", "fmb"])
    ap.add_argument("--consensus", default="exact",
                    choices=list(CONSENSUS_CHOICES),
                    help="exact weighted all-reduce, decentralized gossip "
                         "with per-worker dual replicas, or 8/4-bit "
                         "quantized gossip (more rounds per T_c)")
    ap.add_argument("--graph", default="ring", choices=["ring", "torus"],
                    help="worker gossip graph; torus follows the physical "
                         "(pod, data) mesh extents")
    ap.add_argument("--pipeline", action="store_true",
                    help="staleness-1 pipelined epochs: overlap each "
                         "step's gossip with the next forward/backward")
    ap.add_argument("--gossip-rounds", type=int, default=5)
    ap.add_argument("--compute-time", type=float, default=None,
                    help="AMB budget T; default from Lemma 6")
    ap.add_argument("--comm-time", type=float, default=0.5)
    ap.add_argument("--sim-clock", action="store_true",
                    help="derive b_i(t) from the simulated straggler "
                         "clock (paper evaluation) instead of measured "
                         "per-step wall time")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.data, args.model)
    n = num_workers(mesh)
    gb = n * args.batch_per_worker

    key = jax.random.PRNGKey(args.seed)
    straggler = ShiftedExponential(lam=2.0 / 3.0, zeta=1.0,
                                   b_ref=args.batch_per_worker)
    # Lemma 6: T = (1 + n/b) mu  (simulated-clock units)
    mu = straggler.mean_batch_time()
    t_budget = args.compute_time or (1.0 + n / gb) * mu
    clock = None if args.sim_clock else MeasuredClock(
        straggler, n, args.batch_per_worker)

    beta_sched = BetaSchedule(k=50.0, mu=float(gb), scale=200.0)
    if args.optimizer == "dual_averaging":
        opt = make_optimizer("dual_averaging", beta=beta_sched)
    else:
        opt = make_optimizer(args.optimizer)

    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           seed=args.seed)
    logger = metrics_mod.MetricsLogger(
        args.metrics or f"artifacts/train_{args.arch}_{args.mode}.jsonl")

    gossip = args.consensus != "exact" or args.pipeline
    if gossip and args.optimizer != "dual_averaging":
        raise SystemExit("--consensus gossip / --pipeline run the paper's "
                         "dual-averaging protocol; use --optimizer "
                         "dual_averaging")
    amb_cfg = AMBConfig(
        consensus=args.consensus, gossip_rounds=args.gossip_rounds,
        graph=args.graph, beta=beta_sched, seed=args.seed)

    flush_fn = None
    with use_sharding(mesh):
        params = init_params(key, cfg)
        params = jax.tree.map(
            lambda p, sh: jax.device_put(p, sh), params,
            tree_shardings(params, mesh))
        if gossip:
            if args.pipeline:
                init_state, gstep, flush = make_pipelined_gossip_train_step(
                    cfg, mesh, amb_cfg)
                flush_fn = jax.jit(flush)
            else:
                init_state, gstep = make_gossip_train_step(
                    cfg, mesh, amb_cfg)
            gossip_state = init_state(params)
            gstep_fn = jax.jit(gstep)
        else:
            opt_state = opt.init(params)
            step_fn = jax.jit(make_train_step(cfg, opt, mesh, amb_cfg))

        wall = 0.0
        for step in range(args.steps):
            skey = jax.random.fold_in(key, 10_000 + step)
            if clock is not None:
                times = clock.times(skey)
                budget = args.compute_time or clock.budget()
            else:
                times = straggler.per_gradient_times(
                    skey, n, args.batch_per_worker)
                budget = t_budget
            if args.mode == "amb":
                b = amb_batch_sizes(times, budget)
                # pipelined epochs hide T_c under the next epoch's compute
                wall += max(budget, args.comm_time) if args.pipeline \
                    else budget + args.comm_time
            else:
                b = jnp.full((n,), args.batch_per_worker, jnp.int32)
                wall += float(jnp.max(fmb_finish_times(
                    times, args.batch_per_worker))) + args.comm_time
            batch = stream.batch(0, step, gb)
            batch = shard_batch(batch, mesh,
                                tuple(a for a in ("pod", "data")
                                      if a in mesh.axis_names))
            t0 = time.time()
            if gossip:
                gossip_state, m = gstep_fn(gossip_state, batch, b)
            else:
                params, opt_state, m = step_fn(params, opt_state, batch, b)
            loss = float(m["loss"])
            step_s = time.time() - t0
            if clock is not None:
                clock.update(step_s, float(m["global_batch"]))
            logger.log(step, loss=loss, global_batch=float(m["global_batch"]),
                       sim_wall_s=wall, step_s=step_s,
                       budget_s=float(budget))
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {loss:.4f} "
                      f"b(t)={float(m['global_batch']):.0f} "
                      f"T={float(budget):.3f}s "
                      f"sim_wall={wall:.1f}s")
        if gossip and flush_fn is not None:
            gossip_state = flush_fn(gossip_state)   # settle in-flight gossip
        if args.ckpt_dir:
            if gossip:
                params = gossip_primal(gossip_state, amb_cfg)
            save_checkpoint(args.ckpt_dir, args.steps, params)
            print(f"checkpoint saved to {args.ckpt_dir}")
    logger.close()
    return loss


if __name__ == "__main__":
    main()
