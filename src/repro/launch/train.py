"""AMB training driver: real steps on whatever devices exist.

Runs an LM (reduced or full config) under the AMB protocol: every step the
straggler clock draws per-worker compute times, converts the fixed budget T
into per-worker minibatch sizes b_i(t), and the train step consumes the
masked batch with weighted consensus + dual averaging.  Wall time is
simulated (fixed T + T_c per epoch vs FMB's max_i finish time) exactly as in
the paper's evaluation, while the numerics are the real distributed program.

Example (8 simulated devices, reduced qwen2):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --data 4 --model 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .. import metrics as metrics_mod
from ..ckpt import save_checkpoint
from ..configs import get_config, smoke_config
from ..core.dual_averaging import BetaSchedule
from ..core.stragglers import ShiftedExponential, amb_batch_sizes, fmb_finish_times
from ..data import LMTokenStream, shard_batch
from ..dist import use_sharding
from ..dist.amb import (AMBConfig, gossip_primal, make_gossip_train_step,
                        make_train_step, num_workers)
from ..dist.params import tree_shardings
from ..models import init_params
from ..optim import make_optimizer
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-per-worker", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--optimizer", default="dual_averaging",
                    choices=["dual_averaging", "adamw", "sgd"])
    ap.add_argument("--mode", default="amb", choices=["amb", "fmb"])
    ap.add_argument("--consensus", default="exact",
                    choices=["exact", "gossip"],
                    help="exact weighted all-reduce, or decentralized "
                         "ring gossip with per-worker dual replicas")
    ap.add_argument("--gossip-rounds", type=int, default=5)
    ap.add_argument("--compute-time", type=float, default=None,
                    help="AMB budget T; default from Lemma 6")
    ap.add_argument("--comm-time", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.data, args.model)
    n = num_workers(mesh)
    gb = n * args.batch_per_worker

    key = jax.random.PRNGKey(args.seed)
    straggler = ShiftedExponential(lam=2.0 / 3.0, zeta=1.0,
                                   b_ref=args.batch_per_worker)
    # Lemma 6: T = (1 + n/b) mu
    mu = straggler.mean_batch_time()
    t_budget = args.compute_time or (1.0 + n / gb) * mu

    beta_sched = BetaSchedule(k=50.0, mu=float(gb), scale=200.0)
    if args.optimizer == "dual_averaging":
        opt = make_optimizer("dual_averaging", beta=beta_sched)
    else:
        opt = make_optimizer(args.optimizer)

    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           seed=args.seed)
    logger = metrics_mod.MetricsLogger(
        args.metrics or f"artifacts/train_{args.arch}_{args.mode}.jsonl")

    gossip = args.consensus == "gossip"
    if gossip and args.optimizer != "dual_averaging":
        raise SystemExit("--consensus gossip runs the paper's dual-averaging "
                         "protocol; use --optimizer dual_averaging")
    amb_cfg = AMBConfig(
        consensus=args.consensus, gossip_rounds=args.gossip_rounds,
        beta=beta_sched)

    with use_sharding(mesh):
        params = init_params(key, cfg)
        params = jax.tree.map(
            lambda p, sh: jax.device_put(p, sh), params,
            tree_shardings(params, mesh))
        if gossip:
            init_state, gstep = make_gossip_train_step(cfg, mesh, amb_cfg)
            gossip_state = init_state(params)
            gstep_fn = jax.jit(gstep)
        else:
            opt_state = opt.init(params)
            step_fn = jax.jit(make_train_step(cfg, opt, mesh, amb_cfg))

        wall = 0.0
        for step in range(args.steps):
            skey = jax.random.fold_in(key, 10_000 + step)
            times = straggler.per_gradient_times(
                skey, n, args.batch_per_worker)
            if args.mode == "amb":
                b = amb_batch_sizes(times, t_budget)
                wall += t_budget + args.comm_time
            else:
                b = jnp.full((n,), args.batch_per_worker, jnp.int32)
                wall += float(jnp.max(fmb_finish_times(
                    times, args.batch_per_worker))) + args.comm_time
            batch = stream.batch(0, step, gb)
            batch = shard_batch(batch, mesh,
                                tuple(a for a in ("pod", "data")
                                      if a in mesh.axis_names))
            t0 = time.time()
            if gossip:
                gossip_state, m = gstep_fn(gossip_state, batch, b)
            else:
                params, opt_state, m = step_fn(params, opt_state, batch, b)
            loss = float(m["loss"])
            logger.log(step, loss=loss, global_batch=float(m["global_batch"]),
                       sim_wall_s=wall, step_s=time.time() - t0)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {loss:.4f} "
                      f"b(t)={float(m['global_batch']):.0f} "
                      f"sim_wall={wall:.1f}s")
        if args.ckpt_dir:
            if gossip:
                params = gossip_primal(gossip_state, amb_cfg)
            save_checkpoint(args.ckpt_dir, args.steps, params)
            print(f"checkpoint saved to {args.ckpt_dir}")
    logger.close()
    return loss


if __name__ == "__main__":
    main()
