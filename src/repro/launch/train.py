"""AMB training driver: a thin CLI adapter over :class:`repro.api.AMBSession`.

Every flag maps onto one of the four session specs
(:class:`repro.api.TrainSpec` / :class:`repro.api.ClockSpec` /
:class:`repro.api.ConsensusSpec` / :class:`repro.api.ControllerSpec`);
the session owns the mesh, the clock (measured by default, ``--sim-clock``
restores the paper-evaluation simulated clock — see
:mod:`repro.api.clock`), the consensus strategy, the epoch driver, and —
under ``--controller`` — the online self-tuning loop over budget,
staleness, and batch target.  This driver only selects the input source
and checkpoints; batches flow through the session's prefetched data
plane (``session.run`` — per-worker stream shards, background host
build + device put, ``--prefetch`` buffers deep), and per-epoch metrics
(and controller decisions) are written by the session itself via
``metrics_path``.

Example (8 simulated devices, reduced qwen2, async torus gossip with two
in-flight consensus payloads, self-tuning on):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --data 4 --model 2 --consensus gossip --graph torus \
      --async --staleness 2 --controller
(``--pipeline`` is the staleness-1 special case; ``--restore DIR``
resumes a saved session, controller state included.)

Fault tolerance: ``--churn RATE`` drives the run through a
:class:`repro.faults.PoissonChurn` model (workers leave at RATE per
epoch, rejoin at ``--churn-rejoin``; worker 0 is pinned up) — membership
changes flow through the session's elastic ``set_active`` path, so
consensus re-lays onto the survivors' ring/torus.  Pair with
``--redundancy RHO`` to keep the gradient estimate unbiased while
replica holders are down.
"""
from __future__ import annotations

import argparse

from ..api import (AMBSession, ClockSpec, ConsensusSpec, ControllerSpec,
                   TrainSpec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    TrainSpec.add_cli_args(ap)
    ClockSpec.add_cli_args(ap)
    ConsensusSpec.add_cli_args(ap)
    ControllerSpec.add_cli_args(ap)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="data-plane prefetch depth (batches built + "
                         "device-put ahead of the step; 0 = synchronous)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--restore", default=None, metavar="DIR",
                    help="resume from an AMBSession.save directory "
                         "(params, opt/dual state, and step counter; the "
                         "saved specs override the spec flags)")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--churn", type=float, default=0.0, metavar="RATE",
                    help="Poisson churn: per-epoch leave rate for each "
                         "unpinned worker (0 = off); membership changes "
                         "rebuild consensus over the survivors")
    ap.add_argument("--churn-rejoin", type=float, default=0.5,
                    help="per-epoch rejoin rate for downed workers")
    ap.add_argument("--churn-seed", type=int, default=0,
                    help="fault-trajectory seed (independent of --seed)")
    args = ap.parse_args(argv)

    faults = None
    if args.churn > 0.0:
        from ..faults import PoissonChurn
        faults = PoissonChurn(leave_rate=args.churn,
                              rejoin_rate=args.churn_rejoin,
                              seed=args.churn_seed)

    metrics_path = args.metrics
    try:
        if args.restore:
            session = AMBSession.restore(args.restore,
                                         metrics_path=metrics_path)
            if session.metrics is None:     # keep the arch-derived default
                from ..metrics import MetricsLogger
                session.metrics = MetricsLogger(
                    f"artifacts/train_{session.train.arch}_"
                    f"{session.train.mode}.jsonl")
        else:
            train = TrainSpec.from_args(args)
            session = AMBSession(
                train, ClockSpec.from_args(args),
                ConsensusSpec.from_args(args),
                ControllerSpec.from_args(args),
                metrics_path=metrics_path
                or f"artifacts/train_{train.arch}_{train.mode}.jsonl")
    except ValueError as e:
        raise SystemExit(str(e))
    # session.run draws epochs at the session's own absolute counter, so
    # a restored run continues both the data order and the logged step
    # axis where the saved one stopped instead of re-emitting steps 0..N
    last = session.steps_done + args.steps - 1

    def on_step(step, m):
        if "action" in m:
            print(f"step {step:4d} controller: {m['action']['reason']}")
        if step % 10 == 0 or step == last:
            print(f"step {step:4d} loss {m['loss']:.4f} "
                  f"b(t)={m['global_batch']:.0f} "
                  f"T={m['budget_s']:.3f}s "
                  f"sim_wall={m['sim_wall_s']:.1f}s")

    # the prefetched data plane: per-worker shards of the arch's LM
    # stream (worker i draws stream node i), host build + device put
    # overlapped with the previous epoch's step
    m = session.run(args.steps, prefetch=args.prefetch, on_step=on_step,
                    faults=faults)
    loss = None if m is None else m["loss"]   # zero-step run: no-op
    session.flush()      # settle in-flight gossip (pipelined mode)
    if args.ckpt_dir:
        session.save(args.ckpt_dir)
        print(f"checkpoint saved to {args.ckpt_dir}")
    session.close()
    return loss


if __name__ == "__main__":
    main()
