"""AMB training driver: a thin CLI adapter over :class:`repro.api.AMBSession`.

Every flag maps onto one of the three session specs
(:class:`repro.api.TrainSpec` / :class:`repro.api.ClockSpec` /
:class:`repro.api.ConsensusSpec`); the session owns the mesh, the clock
(measured by default, ``--sim-clock`` restores the paper-evaluation
simulated clock — see :mod:`repro.api.clock`), the consensus strategy and
the epoch driver.  This driver only streams batches, logs metrics, and
checkpoints.

Example (8 simulated devices, reduced qwen2, async torus gossip with two
in-flight consensus payloads):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --data 4 --model 2 --consensus gossip --graph torus \
      --async --staleness 2
(``--pipeline`` is the staleness-1 special case; ``--restore DIR``
resumes a saved session.)
"""
from __future__ import annotations

import argparse

from .. import metrics as metrics_mod
from ..api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
from ..data import LMTokenStream


def main(argv=None):
    ap = argparse.ArgumentParser()
    TrainSpec.add_cli_args(ap)
    ClockSpec.add_cli_args(ap)
    ConsensusSpec.add_cli_args(ap)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--restore", default=None, metavar="DIR",
                    help="resume from an AMBSession.save directory "
                         "(params, opt/dual state, and step counter; the "
                         "saved specs override the spec flags)")
    ap.add_argument("--metrics", default=None)
    args = ap.parse_args(argv)

    try:
        if args.restore:
            session = AMBSession.restore(args.restore)
        else:
            session = AMBSession(TrainSpec.from_args(args),
                                 ClockSpec.from_args(args),
                                 ConsensusSpec.from_args(args))
    except ValueError as e:
        raise SystemExit(str(e))
    train = session.train

    stream = LMTokenStream(vocab_size=session.cfg.vocab_size,
                           seq_len=train.seq_len, seed=train.seed)
    logger = metrics_mod.MetricsLogger(
        args.metrics or f"artifacts/train_{train.arch}_{train.mode}.jsonl")

    loss = None          # a zero-step run is a well-defined no-op
    # absolute step indices (the session's own counter): a restored run
    # continues both the data order and the logged step axis where the
    # saved one stopped instead of re-emitting steps 0..N
    start = session.steps_done
    for step in range(start, start + args.steps):
        m = session.step(stream.batch(0, step, session.global_batch))
        loss = m["loss"]
        logger.log(step, loss=loss, global_batch=m["global_batch"],
                   sim_wall_s=m["sim_wall_s"], step_s=m["step_s"],
                   budget_s=m["budget_s"])
        if step % 10 == 0 or step == start + args.steps - 1:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"b(t)={m['global_batch']:.0f} "
                  f"T={m['budget_s']:.3f}s "
                  f"sim_wall={m['sim_wall_s']:.1f}s")
    session.flush()      # settle in-flight gossip (pipelined mode)
    if args.ckpt_dir:
        session.save(args.ckpt_dir)
        print(f"checkpoint saved to {args.ckpt_dir}")
    logger.close()
    return loss


if __name__ == "__main__":
    main()
