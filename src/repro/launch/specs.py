"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

No device allocation: everything the dry-run lowers against is abstract.
Audio/VLM frontends are stubbed here per the assignment — ``input_specs``
provides frame/patch *embeddings* of the right shape instead of raw
pixels/audio.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import InputShape
from ..models import init_decode_state, init_params
from ..models.common import ArchConfig

SDS = jax.ShapeDtypeStruct


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _nworkers(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def _bspec(mesh: Mesh, batch: int, ndim: int) -> P:
    lead = batch_axes(mesh) if batch % _nworkers(mesh) == 0 else None
    return P(lead, *([None] * (ndim - 1)))


def _sds(mesh: Mesh, shape, dtype, batch_dim0: bool = True) -> SDS:
    spec = _bspec(mesh, shape[0], len(shape)) if batch_dim0 else P()
    return SDS(shape, dtype, sharding=NamedSharding(mesh, spec))


def train_input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {"labels": _sds(mesh, (b, s), jnp.int32)}
    if cfg.input_mode == "embeds":
        batch["embeds"] = _sds(mesh, (b, s, cfg.d_model), cfg.jdtype)
    else:
        batch["tokens"] = _sds(mesh, (b, s), jnp.int32)
    if cfg.family == "audio":
        enc = cfg.encoder_seq or 1500
        batch["enc_embeds"] = _sds(mesh, (b, enc, cfg.d_model), cfg.jdtype)
    return batch


def prefill_input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> dict:
    batch = train_input_specs(cfg, shape, mesh)
    batch.pop("labels")
    return batch


def abstract_params(cfg: ArchConfig):
    """Param ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_decode_state(cfg: ArchConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))


def decode_state_specs(state_sds, mesh: Mesh, global_batch: int):
    """Sharding specs for decode state: batch on worker axes, one large
    inner dim (cache seq / heads / state) on "model" when divisible."""
    baxes = batch_axes(mesh)
    n = _nworkers(mesh)
    msize = mesh.shape["model"]

    def leaf_spec(leaf):
        shp = leaf.shape
        if len(shp) <= 1:
            return P()
        axes: list = [None] * len(shp)
        # dim0 is the stacked-layer dim; dim1 is batch.
        if len(shp) >= 2 and shp[1] == global_batch and global_batch % n == 0:
            axes[1] = baxes
        for i in range(2, len(shp)):
            if shp[i] % msize == 0 and shp[i] >= msize:
                axes[i] = "model"
                break
        return P(*axes)

    return jax.tree.map(leaf_spec, state_sds)


def decode_token_spec(shape: InputShape, mesh: Mesh) -> SDS:
    return _sds(mesh, (shape.global_batch,), jnp.int32)


def worker_batch_spec(mesh: Mesh) -> SDS:
    """b_i(t): per-worker AMB minibatch sizes for this epoch."""
    return SDS((_nworkers(mesh),), jnp.int32,
               sharding=NamedSharding(mesh, P(batch_axes(mesh))))
