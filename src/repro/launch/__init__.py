"""Launchers — thin CLI adapters over :class:`repro.api.AMBSession`.

  * :mod:`repro.launch.train` — AMB/FMB training (``--restore`` resumes
    a saved session; ``--async --staleness D`` selects the AMB-DG
    bounded-staleness epoch driver).
  * :mod:`repro.launch.serve` — decode from a session (``--finetune``
    shares it with training).
  * :mod:`repro.launch.dryrun` — lower/compile cost model on abstract
    inputs (no execution).
  * :mod:`repro.launch.mesh` — host/production mesh construction.
  * :mod:`repro.launch.specs` — abstract input/param specs for dryrun.
"""
