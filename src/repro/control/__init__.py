"""``repro.control`` — online self-tuning of budget, staleness, and batch.

AMB's premise is adapting *work* to a fixed wall-clock budget; this
package closes the loop on the budget itself (and its companions) at
runtime, with no restarts:

  * :mod:`repro.control.telemetry` — :class:`EpochRecord` per epoch
    (measured times, per-node ``b_i(t)``, gradient-noise estimate) and
    the :class:`Telemetry` EMAs over them.
  * :mod:`repro.control.policies` — :class:`BudgetPolicy` (online
    Lemma 6, subsuming the former ``core.extensions.AdaptiveBudget``),
    :class:`StalenessPolicy` (AMB-DG ``D`` / ``gamma = 1/(2D)`` from the
    measured ``T_c/T`` ratio), :class:`BatchDampingPolicy` (effective
    batch target follows the gradient noise scale, adadamp-style).
  * :mod:`repro.control.controller` — one :class:`Controller` that
    consumes records, applies cadence / hysteresis / clipping, and
    emits :class:`ControlAction`\\ s the session actuates.

Configured by :class:`repro.api.specs.ControllerSpec`; wired into
:class:`repro.api.AMBSession` (per-epoch hook) and ``--controller`` in
``launch/train.py``.  This package deliberately imports nothing from
``repro.api`` or ``repro.core`` — it is the bottom of that dependency
stack.
"""
from .controller import ControlAction, Controller                # noqa: F401
from .policies import (BatchDampingPolicy, BudgetPolicy,         # noqa: F401
                       StalenessPolicy)
from .telemetry import EpochRecord, Telemetry                    # noqa: F401

__all__ = [
    "BatchDampingPolicy", "BudgetPolicy", "ControlAction", "Controller",
    "EpochRecord", "StalenessPolicy", "Telemetry",
]
