"""The three control policies: budget, staleness, batch damping.

Each policy is a frozen dataclass that maps smoothed telemetry to a
*proposal* for one knob; the :class:`repro.control.controller.Controller`
owns cadence, hysteresis, and actuation.  Policies are pure — no stored
state beyond what the caller passes — so decisions are reproducible from
a telemetry snapshot (the property the save/restore path leans on).

* :class:`BudgetPolicy` — the online Lemma 6.  Subsumes (and is aliased
  by) the former ``repro.core.extensions.AdaptiveBudget``: re-solve
  ``T = (1 + n/b) mu`` each decision from the EMA'd mean per-gradient
  time ``tau`` (``mu = (b/n) tau``).  The estimator matters: ``tau`` is
  the arithmetic mean over nodes of ``T / b_i`` — inverting the
  aggregate rate ``b(t)/T`` instead converges to the *harmonic* mean of
  the node rates, which by Jensen undershoots Lemma 6's T whenever node
  times are random.
* :class:`StalenessPolicy` — AMB-DG retuning: the async driver's
  per-epoch wall is ``max(T, T_c / D)``, so the smallest staleness that
  keeps epochs compute-bound is ``D = ceil(T_c / T)``.  Track the
  measured ratio, clip to ``[1, d_max]``, and only move when the ratio
  clears the switching boundary by ``hysteresis`` (deadband against
  thrash); ``gamma = 1/(2D)`` rides along (see
  :mod:`repro.dist.async_epochs` for why the damping is load-bearing).
* :class:`BatchDampingPolicy` — adadamp-style noise damping, AMB's
  variable minibatch seen from the statistical end: the value of a
  marginal gradient shrinks once the batch passes the gradient noise
  scale ``B_noise = tr(Sigma) / ||grad L||^2``, and ``B_noise`` grows as
  training drives ``||grad L||`` down.  The policy grows the *effective*
  batch target toward ``alpha * B_noise`` (never shrinks below the
  launch target), rate-limited to ``grow``x per decision and capped by
  the data layout (``b_i <= batch_per_worker`` is a compiled shape).
  The target feeds :class:`BudgetPolicy`'s re-solve, so the batch is
  actuated *through the deadline T* — no recompile, the AMB way.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BudgetPolicy:
    """Online Lemma 6: re-solve the compute budget T from per-node times.

    Two entry points share the same math:

    * :meth:`solve` — the controller path: float in, float out, from a
      telemetry-smoothed ``tau``.
    * :meth:`init` / :meth:`update` — the jit-compatible EMA form the
      single-device reference loop (``run_amb_adaptive``) scans with;
      this is the exact ``AdaptiveBudget`` API, kept verbatim so the
      alias in :mod:`repro.core.extensions` is a pure re-export.

        tau_ema(t+1) = ema * tau_ema(t) + (1 - ema) * mean_i T(t)/b_i(t)
        T(t+1)       = clip((1 + n/b) * (b/n) * tau_ema, t_min, t_max)
    """

    b_target: int
    ema: float = 0.9
    t_min: float = 1e-3
    t_max: float = 1e6

    def solve(self, tau: float, n: int,
              b_target: Optional[int] = None) -> float:
        """Lemma-6 T from a mean per-gradient-time estimate (host floats)."""
        bt = float(self.b_target if b_target is None else b_target)
        mu = (bt / n) * tau
        return float(min(max((1.0 + n / bt) * mu, self.t_min), self.t_max))

    def init(self, t0: float) -> dict:
        # tau < 0 marks "no observation yet": the first update adopts the
        # observed mean per-gradient time outright instead of averaging
        # against the (possibly badly mis-tuned) implied initial value.
        return {"t_budget": jnp.float32(t0), "tau": jnp.float32(-1.0)}

    def update(self, state: dict, b_observed) -> dict:
        """``b_observed``: the (n,) per-node minibatch sizes b_i(t)."""
        b = jnp.maximum(b_observed.astype(jnp.float32), 1.0)
        tau_obs = jnp.mean(state["t_budget"] / b)
        tau = jnp.where(state["tau"] < 0.0, tau_obs,
                        self.ema * state["tau"]
                        + (1.0 - self.ema) * tau_obs)
        n = b_observed.shape[0]
        mu = (self.b_target / n) * tau
        t_new = jnp.clip((1.0 + n / self.b_target) * mu,
                         self.t_min, self.t_max)
        return {"t_budget": t_new, "tau": tau}


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """AMB-DG staleness retuning from the measured ``T_c / T`` ratio.

    ``propose(d_cur, ratio)`` returns the staleness to run next —
    ``d_cur`` itself unless the ratio clears the hysteresis deadband:

    * raise to ``D* = ceil(ratio)`` only when ``ratio > d_cur +
      hysteresis`` (consensus genuinely no longer fits d_cur windows);
    * lower to ``D*`` only when ``ratio < D* + 1 - hysteresis`` holds
      with room, i.e. ``ratio <= d_cur - 1 - hysteresis`` (the shallower
      queue would still keep epochs compute-bound, with margin — less
      staleness is free loss-trajectory improvement).

    A ratio sitting exactly on a boundary therefore never flips D back
    and forth between adjacent values epoch over epoch.
    """

    d_max: int = 8
    hysteresis: float = 0.25

    def target(self, ratio: float) -> int:
        """The unhysteresed ideal: smallest D with ``T_c / D <= T``."""
        return int(min(max(math.ceil(ratio - 1e-9), 1), self.d_max))

    def propose(self, d_cur: int, ratio: float) -> int:
        ideal = self.target(ratio)
        if ideal > d_cur and ratio > d_cur + self.hysteresis:
            return ideal
        if ideal < d_cur and ratio <= d_cur - 1 - self.hysteresis:
            return ideal
        return d_cur

    @staticmethod
    def gamma(d: int) -> float:
        """The delayed-mixing damping that rides with D (1/(2D); 1 at D=1)."""
        return 1.0 if d <= 1 else 1.0 / (2.0 * d)


@dataclasses.dataclass(frozen=True)
class BatchDampingPolicy:
    """Grow the effective batch target as the gradient noise scale grows.

    ``propose(b_cur, noise_scale)`` moves the target toward
    ``alpha * noise_scale``, clipped to ``[b_floor, b_cap]``, never
    shrinking below ``b_floor`` (the launch target) and never growing by
    more than ``grow``x per decision; changes smaller than ``deadband``
    (relative) are suppressed.  Returns ``b_cur`` when no noise
    telemetry is available yet.
    """

    b_floor: int
    b_cap: int
    alpha: float = 1.0
    grow: float = 2.0
    deadband: float = 0.25

    def propose(self, b_cur: int, noise_scale: Optional[float]) -> int:
        if noise_scale is None:
            return b_cur
        want = self.alpha * noise_scale
        want = min(max(want, float(self.b_floor)), float(self.b_cap))
        want = min(want, self.grow * b_cur)       # rate limit
        want = max(want, float(min(b_cur, self.b_cap)))   # grow-only
        prop = int(round(want))
        if abs(prop - b_cur) <= self.deadband * b_cur:
            return b_cur
        return prop
