"""The one controller over every runtime knob: budget, staleness, batch.

``Controller.observe(record)`` is the whole loop: fold the epoch's
:class:`repro.control.telemetry.EpochRecord` into the telemetry EMAs,
and — on the decision cadence, after warm-up — consult the three
policies and emit a :class:`ControlAction` naming only the knobs that
actually move.  The session applies the action (budget into the Clock,
staleness by drain-and-rebuild); the controller itself never touches
jax state, so it is trivially picklable into ``session.json`` and a
restored run replays the same decisions bit for bit.

Anti-thrash is layered deliberately:

* **cadence** — at most one decision per ``interval`` epochs, none
  before ``warmup`` (the EMAs need samples before they mean anything);
* **EMA smoothing** — policies see only telemetry EMAs, never raw draws;
* **deadbands** — relative budget moves under ``deadband`` and batch
  moves under the batch policy's own deadband are suppressed;
* **rate limits / clips** — budget moves at most ``max_step``x per
  decision; staleness moves only when the ratio clears the
  :class:`~repro.control.policies.StalenessPolicy` hysteresis band.

Decision order matters and is fixed: batch first (a bigger effective
batch changes what Lemma 6 should solve for), then budget (re-solved at
the possibly-new target), then staleness (the ``T_c / T`` ratio is
evaluated against the budget that will actually be in force next epoch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .policies import BatchDampingPolicy, BudgetPolicy, StalenessPolicy
from .telemetry import EpochRecord, Telemetry


@dataclasses.dataclass
class ControlAction:
    """One decision: only the knobs that move are non-None."""

    epoch: int
    budget: Optional[float] = None       # new compute budget T (seconds)
    staleness: Optional[int] = None      # new D (async driver)
    gamma: Optional[float] = None        # 1/(2D) companion of `staleness`
    b_target: Optional[int] = None       # new effective-batch target
    reason: str = ""

    @property
    def nontrivial(self) -> bool:
        return (self.budget is not None or self.staleness is not None
                or self.b_target is not None)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Controller:
    """Telemetry in, :class:`ControlAction` out; pure host-side state.

    Args:
      spec: a :class:`repro.api.specs.ControllerSpec` (duck-typed — only
        its scalar fields are read, keeping this package import-free of
        ``repro.api``).
      n_workers: worker count (Lemma 6's n).
      comm_time: the consensus window T_c (seconds).
      b_target: launch effective-batch target (Lemma 6's b); also the
        batch policy's floor.
      b_cap: hard batch ceiling — ``n * batch_per_worker``, the compiled
        data layout's per-epoch maximum.
      staleness: the staleness D in force at launch.
      async_mode: whether the session runs the async driver (staleness
        retuning is meaningless — and suppressed — otherwise).
    """

    def __init__(self, spec, *, n_workers: int, comm_time: float,
                 b_target: int, b_cap: int, staleness: int = 1,
                 async_mode: bool = False):
        self.spec = spec
        self.n = int(n_workers)
        self.comm_time = float(comm_time)
        self.async_mode = bool(async_mode)
        self.telemetry = Telemetry(ema=spec.ema)
        self.budget_policy = BudgetPolicy(b_target=int(b_target))
        self.staleness_policy = StalenessPolicy(d_max=spec.d_max,
                                                hysteresis=spec.hysteresis)
        self.batch_policy = BatchDampingPolicy(b_floor=int(b_target),
                                               b_cap=int(b_cap))
        # live knob values (actuated state the session mirrors)
        self.b_target = int(b_target)
        self.staleness = int(staleness)
        self.budget: Optional[float] = None   # adopted from first record
        self._since_decision = 0
        self.decisions = 0                    # non-trivial actions emitted

    # -- the loop ----------------------------------------------------------

    def observe(self, rec: EpochRecord) -> Optional[ControlAction]:
        """Fold one epoch's record; maybe emit an action (see cadence)."""
        self.telemetry.update(rec)
        if self.budget is None:
            self.budget = float(rec.budget_s)
        self._since_decision += 1
        if (self.telemetry.epochs_seen < self.spec.warmup
                or self._since_decision < self.spec.interval):
            return None
        self._since_decision = 0
        action = self._decide(rec.t)
        if action is None or not action.nontrivial:
            return None
        self.decisions += 1
        return action

    def _decide(self, epoch: int) -> Optional[ControlAction]:
        spec = self.spec
        action = ControlAction(epoch=epoch)
        reasons = []

        # 1) batch damping: the target Lemma 6 solves for next
        if spec.batch:
            prop = self.batch_policy.propose(self.b_target,
                                             self.telemetry.noise_scale)
            if prop != self.b_target:
                reasons.append(f"b_target {self.b_target}->{prop} "
                               f"(noise_scale~{self.telemetry.noise_scale:.1f})")
                self.b_target = prop
                action.b_target = prop

        # 2) budget: online Lemma 6 at the (possibly new) target
        if spec.budget and self.telemetry.tau is not None:
            want = self.budget_policy.solve(self.telemetry.tau, self.n,
                                            b_target=self.b_target)
            cur = self.budget
            want = min(max(want, cur / spec.max_step), cur * spec.max_step)
            if abs(want - cur) > spec.deadband * max(cur, 1e-12):
                reasons.append(f"T {cur:.4g}->{want:.4g} "
                               f"(tau~{self.telemetry.tau:.4g})")
                self.budget = want
                action.budget = want

        # 3) staleness: T_c over the budget that will be in force
        if spec.staleness and self.async_mode and self.budget:
            ratio = self.comm_time / max(self.budget, 1e-12)
            prop = self.staleness_policy.propose(self.staleness, ratio)
            if prop != self.staleness:
                reasons.append(f"D {self.staleness}->{prop} "
                               f"(T_c/T~{ratio:.2f})")
                self.staleness = prop
                action.staleness = prop
                action.gamma = self.staleness_policy.gamma(prop)

        action.reason = "; ".join(reasons)
        return action

    # -- save / restore ----------------------------------------------------

    def to_state(self) -> dict:
        """JSON-ready snapshot; with the spec, fully determines future
        decisions — the bit-exact-resume contract."""
        return {"telemetry": self.telemetry.to_state(),
                "b_target": self.b_target, "staleness": self.staleness,
                "budget": self.budget,
                "since_decision": self._since_decision,
                "decisions": self.decisions}

    def load_state(self, state: dict) -> None:
        self.telemetry = Telemetry.from_state(state["telemetry"])
        self.b_target = int(state["b_target"])
        self.staleness = int(state["staleness"])
        self.budget = (None if state.get("budget") is None
                       else float(state["budget"]))
        self._since_decision = int(state.get("since_decision", 0))
        self.decisions = int(state.get("decisions", 0))
