"""Per-epoch runtime telemetry for the online controller.

One :class:`EpochRecord` per ``AMBSession.step``: the measured compute /
consensus times, the realized per-node minibatch sizes ``b_i(t)``, and —
when the step was built with ``noise_stats`` (see
:func:`repro.dist.amb.grad_noise_stats`) — a cheap minibatch
gradient-noise estimate from the *between-worker* dispersion of the
per-worker mean gradients.  :class:`Telemetry` accumulates the records
into EMAs; the policies in :mod:`repro.control.policies` read only these
smoothed signals, never raw epochs, so a single noisy draw cannot flip a
decision.

The noise estimate, in McCandlish-et-al. "gradient noise scale" form:
worker i's mean gradient over ``b_i`` samples has covariance
``Sigma / b_i``, so the b-weighted dispersion around the eq.-6 weighted
mean,  ``Dw = sum_i (b_i/B) ||g_i - gbar||^2``,  has expectation
``tr(Sigma) (n-1)/B``.  Hence ``tr(Sigma) ~= Dw B/(n-1)`` and the
*unbiased* squared full-gradient norm is ``||gbar||^2 - Dw/(n-1)``
(the raw ``||gbar||^2`` is inflated by ``tr(Sigma)/B``).  Their ratio —
the noise scale ``B_noise = tr(Sigma) / ||grad L||^2`` — is the batch
size at which averaging stops paying, the signal
:class:`repro.control.policies.BatchDampingPolicy` tracks.  Numerator
and denominator are EMA'd separately (a ratio of EMAs is far more
stable than an EMA of ratios when the denominator passes near zero).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class EpochRecord:
    """What one AMB epoch actually measured (host-side floats only)."""

    t: int                    # epoch index (session step counter)
    budget_s: float           # compute budget T applied this epoch
    comm_time_s: float        # consensus window T_c
    step_s: float             # measured host wall time of the step
    loss: float
    b: np.ndarray             # (n,) realized per-worker minibatch b_i(t)
    global_batch: float       # sum_i min(b_i, per-worker cap)
    staleness: int = 1        # D in force when the epoch ran
    tau_s: Optional[float] = None          # measured mean per-grad seconds
    grad_sq_norm: Optional[float] = None   # ||gbar||^2 (biased; see above)
    grad_var: Optional[float] = None       # Dw, the b-weighted dispersion


class Telemetry:
    """EMAs over :class:`EpochRecord` streams — the controller's senses.

    Tracked signals (all ``None`` until first observed):

    * ``tau`` — mean per-gradient seconds.  Preferred source is the
      record's measured ``tau_s`` (elapsed time of the gradients each
      node actually finished, divided by its count — exact even when
      b_i saturates the per-worker data cap); the fallback is
      ``mean_i T / b_i``, which is the right arithmetic-mean-over-nodes
      form Lemma 6 wants (inverting the aggregate rate would converge
      to the harmonic mean and undershoot) but *over*-estimates
      whenever a node hits the cap early and idles out the window —
      under that bias the Lemma-6 re-solve is a positive feedback loop,
      which is why the session always supplies ``tau_s``.
    * ``ratio`` — the consensus-to-compute ratio ``T_c / T`` the
      AMB-DG staleness retuning keys on.
    * ``trace_sigma`` / ``grad_sq`` — gradient-noise numerator and
      (bias-corrected) denominator; ``noise_scale`` is their ratio.
    * ``loss`` — smoothed train loss (logging / guardrails).
    """

    def __init__(self, ema: float = 0.8):
        self.ema = float(ema)
        self.tau: Optional[float] = None
        self.ratio: Optional[float] = None
        self.trace_sigma: Optional[float] = None
        self.grad_sq: Optional[float] = None
        self.loss: Optional[float] = None
        self.epochs_seen = 0

    def _fold(self, cur: Optional[float], obs: float) -> float:
        if cur is None:
            return float(obs)
        return self.ema * cur + (1.0 - self.ema) * float(obs)

    def update(self, rec: EpochRecord) -> None:
        b = np.maximum(np.asarray(rec.b, dtype=np.float64), 1.0)
        n = int(b.shape[0])
        if rec.tau_s is not None:
            self.tau = self._fold(self.tau, rec.tau_s)
        elif rec.budget_s > 0.0:
            self.tau = self._fold(self.tau, float(np.mean(rec.budget_s / b)))
        if rec.budget_s > 0.0:
            self.ratio = self._fold(self.ratio,
                                    rec.comm_time_s / rec.budget_s)
        self.loss = self._fold(self.loss, rec.loss)
        if (rec.grad_sq_norm is not None and rec.grad_var is not None
                and n > 1 and rec.global_batch >= 1.0):
            big_b = float(rec.global_batch)
            tr = rec.grad_var * big_b / (n - 1)
            g2 = max(rec.grad_sq_norm - rec.grad_var / (n - 1), 0.0)
            self.trace_sigma = self._fold(self.trace_sigma, tr)
            self.grad_sq = self._fold(self.grad_sq, g2)
        self.epochs_seen += 1

    @property
    def noise_scale(self) -> Optional[float]:
        """``tr(Sigma) / ||grad L||^2`` — None until noise stats arrive."""
        if self.trace_sigma is None or self.grad_sq is None:
            return None
        return self.trace_sigma / max(self.grad_sq, 1e-12)

    # -- save / restore ----------------------------------------------------

    def to_state(self) -> dict:
        return {"ema": self.ema, "tau": self.tau, "ratio": self.ratio,
                "trace_sigma": self.trace_sigma, "grad_sq": self.grad_sq,
                "loss": self.loss, "epochs_seen": self.epochs_seen}

    @classmethod
    def from_state(cls, state: dict) -> "Telemetry":
        t = cls(ema=state.get("ema", 0.8))
        for k in ("tau", "ratio", "trace_sigma", "grad_sq", "loss"):
            setattr(t, k, state.get(k))
        t.epochs_seen = int(state.get("epochs_seen", 0))
        return t
