"""Model zoo: 10 assigned architectures over one functional core."""
from .common import ArchConfig
from .model import (DecodeState, decode_step, forward, init_decode_state,
                    init_params, lm_loss, logits_fn, param_count, prefill)

__all__ = ["ArchConfig", "DecodeState", "decode_step", "forward",
           "init_decode_state", "init_params", "lm_loss", "logits_fn",
           "param_count", "prefill"]
