"""Model zoo: 10 assigned architectures over one functional core."""
from .common import ArchConfig
from .model import DecodeState, decode_step, evict_decode_state, forward
from .model import init_decode_state, init_params, insert_decode_state
from .model import lm_loss, logits_fn, param_count, prefill

__all__ = ["ArchConfig", "DecodeState", "decode_step", "evict_decode_state",
           "forward", "init_decode_state", "init_params",
           "insert_decode_state", "lm_loss", "logits_fn", "param_count",
           "prefill"]
