"""Sequence-state models: Mamba2 (SSD) and RWKV6 ("Finch") blocks.

Both are implemented twice:
  * chunked parallel form for training / prefill (lax.scan over chunks with a
    matmul-heavy intra-chunk computation — the TPU-friendly formulation; the
    Pallas kernel in ``repro/kernels/rwkv6_scan`` implements the same chunk
    step for VMEM), and
  * O(1)-state recurrent step for decode (``*_decode``), which is what makes
    ``long_500k`` native for these families.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, init_linear, scan_or_unroll

Array = jax.Array


# ---------------------------------------------------------------------------
# Mamba2 (SSD, scalar-per-head decay)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    hd = 64
    heads = d_in // hd
    return d_in, heads, hd


def mamba2_params(key: Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, heads, hd = mamba2_dims(cfg)
    ns = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        # projections: x, z (gate), B, C, dt
        "w_in": init_linear(ks[0], (d, 2 * d_in + 2 * ns + heads), cfg.jdtype),
        "conv_w": init_linear(ks[1], (cfg.conv_width, d_in + 2 * ns),
                              cfg.jdtype, scale=0.5),
        "a_log": jnp.zeros((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "w_out": init_linear(ks[2], (d_in, d), cfg.jdtype),
        "norm_z": jnp.ones((d_in,), jnp.float32),
    }


class MambaState(NamedTuple):
    h: Array        # (B, heads, hd, ns) SSM state
    conv: Array     # (B, conv_width - 1, d_conv) conv tail


def _mamba_split(p: dict, x: Array, cfg: ArchConfig):
    d_in, heads, hd = mamba2_dims(cfg)
    ns = cfg.ssm_state
    proj = x @ p["w_in"]
    xz, rest = jnp.split(proj, [2 * d_in], axis=-1)
    xi, z = jnp.split(xz, 2, axis=-1)
    bc, dt = jnp.split(rest, [2 * ns], axis=-1)
    return xi, z, bc, dt                     # (..., d_in), (..., d_in), (..., 2ns), (..., heads)


def _causal_conv(u: Array, w: Array, tail: Array | None):
    """Depthwise causal conv. u: (B, S, C); w: (K, C); tail: (B, K-1, C)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(k))
    new_tail = up[:, -(k - 1):] if k > 1 else tail
    return jax.nn.silu(out), new_tail


def mamba2_forward(p: dict, x: Array, cfg: ArchConfig,
                   chunk: int = 0, return_state: bool = False):
    """Training/prefill: x (B, S, d) -> (B, S, d). Chunked SSD scan.

    With ``return_state`` also returns the MambaState after the sequence
    (decode handoff for prefill)."""
    chunk = chunk or cfg.ssm_chunk
    b, s, d = x.shape
    d_in, heads, hd = mamba2_dims(cfg)
    ns = cfg.ssm_state
    xi, z, bc, dt = _mamba_split(p, x, cfg)
    conv_in = jnp.concatenate([xi, bc], axis=-1)
    conv_tail = conv_in[:, -(cfg.conv_width - 1):] if s >= cfg.conv_width - 1 \
        else jnp.pad(conv_in, ((0, 0), (cfg.conv_width - 1 - s, 0), (0, 0)))
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], None)
    xi, bc = conv_out[..., :d_in], conv_out[..., d_in:]
    bmat, cmat = jnp.split(bc, 2, axis=-1)                 # (B,S,ns) each
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,heads)
    a = -jnp.exp(p["a_log"])                               # (heads,)
    decay = jnp.exp(dt * a)                                # (B,S,heads) in (0,1)

    xh = xi.reshape(b, s, heads, hd).astype(jnp.float32)
    xh = xh * dt[..., None]                                # dt-scaled input
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)
    xh = xh.reshape(b, nchunks, chunk, heads, hd)
    bm = bmat.reshape(b, nchunks, chunk, ns).astype(jnp.float32)
    cm = cmat.reshape(b, nchunks, chunk, ns).astype(jnp.float32)
    dc = decay.reshape(b, nchunks, chunk, heads)

    def chunk_step(h, inp):
        xc, bc_, cc, dcc = inp                 # (B,chunk,heads,hd) etc
        logd = jnp.log(jnp.maximum(dcc, 1e-20))
        cums = jnp.cumsum(logd, axis=1)        # (B,chunk,heads)
        # intra-chunk: y[t] = sum_{u<=t} exp(cums[t]-cums[u]) C_t.B_u x_u
        qk = jnp.einsum("bts,bus->btu", cc, bc_)             # (B,chunk,chunk)
        rel = cums[:, :, None, :] - cums[:, None, :, :]      # (B,t,u,heads)
        tri = (jnp.arange(xc.shape[1])[:, None]
               >= jnp.arange(xc.shape[1])[None, :])
        gate = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        y_intra = jnp.einsum("btu,btuh,buhd->bthd", qk, gate, xc)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bts,bth,bhds->bthd",
                             cc, jnp.exp(cums), h)
        # state update: h' = (prod decay) h + sum_u (prod_{>u} decay) B_u x_u
        total = cums[:, -1]                                   # (B,heads)
        w_u = jnp.exp(total[:, None, :] - cums)               # (B,chunk,heads)
        h_new = (jnp.exp(total)[:, :, None, None] * h
                 + jnp.einsum("buh,buhd,bus->bhds", w_u, xc, bc_))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, heads, hd, ns), jnp.float32)
    h_final, ys = scan_or_unroll(chunk_step, h0,
                                 (xh.transpose(1, 0, 2, 3, 4),
                                  bm.transpose(1, 0, 2, 3),
                                  cm.transpose(1, 0, 2, 3),
                                  dc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, heads, hd)
    y = y[:, :s]
    xh_unpad = xi.reshape(b, s, heads, hd).astype(jnp.float32)
    y = y + p["d_skip"][None, None, :, None] * xh_unpad       # D skip
    y = y.reshape(b, s, d_in)
    # gated RMSNorm output
    zf = jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_z"] * zf
    out = (y.astype(x.dtype)) @ p["w_out"]
    if return_state:
        return out, MambaState(h_final, conv_tail.astype(cfg.jdtype))
    return out


def mamba2_init_state(cfg: ArchConfig, batch: int) -> MambaState:
    d_in, heads, hd = mamba2_dims(cfg)
    ns = cfg.ssm_state
    return MambaState(
        h=jnp.zeros((batch, heads, hd, ns), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * ns), cfg.jdtype))


def mamba2_decode(p: dict, x: Array, state: MambaState,
                  cfg: ArchConfig) -> tuple[Array, MambaState]:
    """One-token step. x: (B, 1, d)."""
    b = x.shape[0]
    d_in, heads, hd = mamba2_dims(cfg)
    ns = cfg.ssm_state
    xi, z, bc, dt = _mamba_split(p, x, cfg)
    conv_in = jnp.concatenate([xi, bc], axis=-1)              # (B,1,dc)
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], state.conv)
    xi, bc = conv_out[..., :d_in], conv_out[..., d_in:]
    bmat, cmat = jnp.split(bc[:, 0], 2, axis=-1)              # (B,ns)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    decay = jnp.exp(dtv * (-jnp.exp(p["a_log"])))             # (B,heads)
    xh = xi[:, 0].reshape(b, heads, hd).astype(jnp.float32) * dtv[..., None]
    h_new = (decay[..., None, None] * state.h
             + jnp.einsum("bhd,bs->bhds", xh, bmat.astype(jnp.float32)))
    y = jnp.einsum("bhds,bs->bhd", h_new, cmat.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xi[:, 0].reshape(
        b, heads, hd).astype(jnp.float32)
    y = y.reshape(b, 1, d_in)
    zf = jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_z"] * zf
    return (y.astype(x.dtype)) @ p["w_out"], MambaState(h_new, new_tail)


# ---------------------------------------------------------------------------
# RWKV6 ("Finch") time-mix with data-dependent decay
# ---------------------------------------------------------------------------

RWKV_HD = 64


def rwkv6_dims(cfg: ArchConfig):
    heads = cfg.d_model // RWKV_HD
    return heads, RWKV_HD


def rwkv6_state_heads(cfg: ArchConfig) -> int:
    """Head count of the wkv state, padded for head-aligned sharding.

    40 heads on a 16-way model axis = 2.5 heads/chip: the partitioner must
    exchange state slices at head boundaries every token.  Padding to
    ``cfg.head_pad_to`` (48 -> 3 heads/chip) makes every per-head state op
    local.  Exact: padded channels carry r = k = v = 0, so their state rows
    stay identically zero.
    """
    heads, _ = rwkv6_dims(cfg)
    if cfg.head_pad_to and cfg.head_pad_to > heads:
        return cfg.head_pad_to
    return heads


def _pad_heads(t: Array, cfg: ArchConfig, value: float = 0.0) -> Array:
    """Pad the trailing flat channel dim from heads*hd to padded heads*hd."""
    heads, hd = rwkv6_dims(cfg)
    ph = rwkv6_state_heads(cfg)
    if ph == heads:
        return t
    pad = [(0, 0)] * (t.ndim - 1) + [(0, (ph - heads) * hd)]
    return jnp.pad(t, pad, constant_values=value)


def rwkv6_params(key: Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    heads, hd = rwkv6_dims(cfg)
    lora = 64
    ks = jax.random.split(key, 8)
    return {
        "mu": 0.5 * jnp.ones((4, d), cfg.jdtype),   # token-shift mix r,k,v,w
        "w_r": init_linear(ks[0], (d, d), cfg.jdtype),
        "w_k": init_linear(ks[1], (d, d), cfg.jdtype),
        "w_v": init_linear(ks[2], (d, d), cfg.jdtype),
        "w_g": init_linear(ks[3], (d, d), cfg.jdtype),
        "decay_a": init_linear(ks[4], (d, lora), cfg.jdtype),
        "decay_b": init_linear(ks[5], (lora, d), cfg.jdtype),
        "decay_bias": -6.0 * jnp.ones((d,), jnp.float32),
        "u_bonus": jnp.zeros((heads, hd), jnp.float32),
        "w_out": init_linear(ks[6], (d, d), cfg.jdtype),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def _rwkv_proj(p: dict, x: Array, x_prev: Array, cfg: ArchConfig):
    """Token-shift projections. x: (B,S,d); x_prev: (B,S,d) shifted input."""
    mu = p["mu"]
    def mix(i):
        return x * mu[i] + x_prev * (1.0 - mu[i])
    r = mix(0) @ p["w_r"]
    k = mix(1) @ p["w_k"]
    v = mix(2) @ p["w_v"]
    wdec = (mix(3) @ p["decay_a"]) @ p["decay_b"]
    wdec = -jnp.exp(p["decay_bias"] + wdec.astype(jnp.float32))  # log-decay < 0
    decay = jnp.exp(wdec)                                        # (B,S,d) in (0,1)
    g = jax.nn.silu(x @ p["w_g"])
    return r, k, v, decay, g


def rwkv6_forward(p: dict, x: Array, cfg: ArchConfig,
                  chunk: int = 0, return_state: bool = False):
    """Training/prefill chunked linear attention with per-channel decay."""
    chunk = chunk or cfg.ssm_chunk
    b, s, d = x.shape
    heads, hd = rwkv6_dims(cfg)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, decay, g = _rwkv_proj(p, x, x_prev, cfg)
    # Training runs with NATIVE heads (padding costs ~+10% train memory for
    # nothing — the per-token state exchange only hurts decode); the padded
    # layout is applied at the decode handoff below and inside rwkv6_decode.
    ph = heads

    def hsplit(t):
        return t.reshape(b, s, ph, hd).astype(jnp.float32)
    r, k, v, dc = hsplit(r), hsplit(k), hsplit(v), hsplit(decay)
    u = p["u_bonus"]

    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (r, k, v))
        dc = jnp.pad(dc, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
    def ch(t):
        return t.reshape(b, nchunks, chunk, ph, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, dcc = ch(r), ch(k), ch(v), ch(dc)

    def chunk_step(state, inp):
        rb, kb, vb, db = inp                  # (B,chunk,heads,hd)
        logd = jnp.log(jnp.maximum(db, 1e-20))
        cums = jnp.cumsum(logd, axis=1)       # (B,chunk,heads,hd)
        # inter-chunk: y_t += (r_t * prod_{<=t-1} d) @ state
        # (exponent clips: see kernels/rwkv6_scan.py — only active when the
        # true coefficient underflows anyway)
        rd = rb * jnp.exp(jnp.clip(cums - logd, -60.0, 60.0))
        y_inter = jnp.einsum("bthd,bhde->bthe", rd, state)
        # intra-chunk: y_t += sum_{u<t} (r_t . (d-prods) k_u) v_u + u-bonus diag
        # coefficient of k_u v_u at step t is prod_{s=u+1}^{t-1} d_s
        kd = kb * jnp.exp(jnp.clip(-cums, -60.0, 60.0))
        att = jnp.einsum("bthd,buhd->bthu", rd, kd)
        tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        att = jnp.where(tri[None, :, None, :], att, 0.0)
        y_intra = jnp.einsum("bthu,buhe->bthe", att, vb)
        # current-token bonus: r_t . (u * k_t) v_t
        bonus = jnp.einsum("bthd,bthd->bth", rb, u[None, None] * kb)
        y_bonus = bonus[..., None] * vb
        # state update: state' = prod(d) state + sum_u (prod_{>u} d) k_u v_u
        total = cums[:, -1]                    # (B,heads,hd)
        wu = jnp.exp(total[:, None] - cums)    # (B,chunk,heads,hd)
        state_new = (jnp.exp(total)[..., None] * state
                     + jnp.einsum("buhd,buhe->bhde", kb * wu, vb))
        return state_new, y_inter + y_intra + y_bonus

    s0 = jnp.zeros((b, ph, hd, hd), jnp.float32)
    s_final, ys = scan_or_unroll(chunk_step, s0, (rc, kc, vc, dcc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, ph * hd)
    y = y[:, :s]
    # group-norm-ish output norm + gate (padded heads are all-zero: their
    # var is 0 and the normalised rows stay 0)
    yh = y.reshape(b, s, ph, hd)
    var = jnp.mean(yh * yh, axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + 1e-6)
    y = yh.reshape(b, s, ph * hd)[:, :, :d] * p["ln_x"]
    out = (y * g.astype(jnp.float32)).astype(x.dtype) @ p["w_out"]
    if return_state:
        # NOTE: s_final includes padded steps with decay=1, k=v=0 — a no-op
        # on the state, so it is exactly the state after token s.  Pad the
        # head dim to the decode (sharding-aligned) layout here.
        php = rwkv6_state_heads(cfg)
        if php != heads:
            s_final = jnp.pad(
                s_final, ((0, 0), (0, php - heads), (0, 0), (0, 0)))
        return out, RWKVState(s_final, x[:, -1])
    return out


class RWKVState(NamedTuple):
    s: Array          # (B, heads, hd, hd) wkv state
    x_prev: Array     # (B, d) last input (token shift)


def rwkv6_init_state(cfg: ArchConfig, batch: int) -> RWKVState:
    _, hd = rwkv6_dims(cfg)
    ph = rwkv6_state_heads(cfg)
    return RWKVState(jnp.zeros((batch, ph, hd, hd), jnp.float32),
                     jnp.zeros((batch, cfg.d_model), cfg.jdtype))


def rwkv6_decode(p: dict, x: Array, state: RWKVState,
                 cfg: ArchConfig) -> tuple[Array, RWKVState]:
    """One-token step. x: (B, 1, d)."""
    b, _, d = x.shape
    heads, hd = rwkv6_dims(cfg)
    xp = state.x_prev[:, None, :]
    r, k, v, decay, g = _rwkv_proj(p, x, xp, cfg)
    ph = rwkv6_state_heads(cfg)
    r, k, v = (_pad_heads(t, cfg) for t in (r, k, v))
    decay = _pad_heads(decay, cfg, value=1.0)
    def hs(t):
        return t[:, 0].reshape(b, ph, hd).astype(jnp.float32)
    r, k, v, dc = hs(r), hs(k), hs(v), hs(decay)
    u = _pad_heads(p["u_bonus"].reshape(-1), cfg).reshape(ph, hd)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = jnp.einsum("bhd,bhde->bhe", r, state.s + u[..., None] * kv)
    s_new = dc[..., None] * state.s + kv
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)).reshape(b, 1, ph * hd)[:, :, :d] \
        * p["ln_x"]
    out = (y * g.astype(jnp.float32)).astype(x.dtype) @ p["w_out"]
    return out, RWKVState(s_new, x[:, 0])
