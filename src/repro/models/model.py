"""Model assembly: init, training forward/loss, prefill, one-token decode.

One code path serves all 10 assigned architectures, keyed by
``ArchConfig.family``:

  dense / vlm      decoder-only transformer (GQA, optional qk_norm/bias/SWA)
  moe              dense attention + top-k MoE FFN
  ssm              RWKV6 time-mix + channel-mix (attention-free)
  hybrid           Mamba2 backbone + a *shared* attention block every k layers
                   (Zamba2 pattern)
  audio            whisper-style encoder-decoder (frontend stubbed: encoder
                   consumes precomputed frame embeddings)

Homogeneous stacks are ``lax.scan``-ed over stacked params (keeps HLO size
O(1) in depth — critical for the 80-compile dry-run matrix) with
``jax.checkpoint`` per block for training memory.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import ArchConfig, init_linear, rms_norm, swiglu

Array = jax.Array

# --------------------------------------------------------------------------
# Layer-stack iteration: lax.scan normally (O(1) HLO in depth), or an
# unrolled python loop under ``unrolled_layers()`` — used by the dry-run's
# cost-extrapolation compiles, because XLA cost_analysis counts while-loop
# bodies exactly once regardless of trip count.
# --------------------------------------------------------------------------

from .common import scan_or_unroll as scan_layers  # noqa: E402
from .common import unrolled_loops as unrolled_layers  # noqa: E402


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _mlp_params(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": init_linear(ks[0], (d, ff), cfg.jdtype),
        "w_up": init_linear(ks[1], (d, ff), cfg.jdtype),
        "w_down": init_linear(ks[2], (ff, d), cfg.jdtype),
    }


def _dense_block_params(key: Array, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.attention_params(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.moe_params(k2, cfg)
    else:
        p["mlp"] = _mlp_params(k2, cfg)
    return p


def _rwkv_block_params(key: Array, cfg: ArchConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "tmix": ssm_mod.rwkv6_params(k1, cfg),
        "ln2": jnp.ones((d,), jnp.float32),
        "cmix": {
            "mu": 0.5 * jnp.ones((2, d), cfg.jdtype),
            "w_k": init_linear(k2, (d, ff), cfg.jdtype),
            "w_v": init_linear(k3, (ff, d), cfg.jdtype),
            "w_r": init_linear(k4, (d, d), cfg.jdtype),
        },
    }


def _mamba_block_params(key: Array, cfg: ArchConfig) -> dict:
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "mamba": ssm_mod.mamba2_params(key, cfg),
    }


def _encdec_dec_block_params(key: Array, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.attention_params(k1, cfg),
        "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
        "xattn": attn.attention_params(k2, cfg, cross=True),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": _mlp_params(k3, cfg),
    }


def _block_param_fn(cfg: ArchConfig):
    return {
        "dense": _dense_block_params,
        "moe": _dense_block_params,
        "vlm": _dense_block_params,
        "ssm": _rwkv_block_params,
        "hybrid": _mamba_block_params,
        "audio": _encdec_dec_block_params,
    }[cfg.family]


def init_params(key: Array, cfg: ArchConfig) -> dict:
    keys = jax.random.split(key, 8)
    block_fn = _block_param_fn(cfg)
    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: block_fn(k, cfg))(layer_keys)

    params: dict[str, Any] = {
        "embed": init_linear(keys[1], (cfg.padded_vocab, cfg.d_model),
                             cfg.jdtype, scale=1.0),
        "unembed": init_linear(keys[2], (cfg.d_model, cfg.padded_vocab),
                               cfg.jdtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "blocks": blocks,
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = _dense_block_params(keys[3], cfg)
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _dense_block_params(k, cfg))(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Block forward (training / prefill)
# ---------------------------------------------------------------------------

def _dense_block_fwd(p: dict, x: Array, positions: Array, cfg: ArchConfig,
                     *, causal: bool = True) -> tuple[Array, Array]:
    window = cfg.sliding_window
    h = attn.attend_train(p["attn"], rms_norm(x, p["ln1"]), positions, cfg,
                          causal=causal, window=window)
    x = x + h
    x = constrain(x, "batch", "seq", None)
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        h, aux = moe_mod.moe_forward(p["moe"], rms_norm(x, p["ln2"]), cfg)
    else:
        mp = p["mlp"]
        h = swiglu(rms_norm(x, p["ln2"]), mp["w_gate"], mp["w_up"],
                   mp["w_down"])
    x = x + h
    return constrain(x, "batch", "seq", None), aux


def _rwkv_block_fwd(p: dict, x: Array, cfg: ArchConfig) -> Array:
    x = x + ssm_mod.rwkv6_forward(p["tmix"], rms_norm(x, p["ln1"]), cfg)
    x = constrain(x, "batch", "seq", None)
    xn = rms_norm(x, p["ln2"])
    cm = p["cmix"]
    xp = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    k_in = xn * cm["mu"][0] + xp * (1 - cm["mu"][0])
    r_in = xn * cm["mu"][1] + xp * (1 - cm["mu"][1])
    v = jnp.square(jax.nn.relu(k_in @ cm["w_k"])) @ cm["w_v"]
    x = x + jax.nn.sigmoid(r_in @ cm["w_r"]) * v
    return constrain(x, "batch", "seq", None)


def _mamba_block_fwd(p: dict, x: Array, cfg: ArchConfig) -> Array:
    x = x + ssm_mod.mamba2_forward(p["mamba"], rms_norm(x, p["ln1"]), cfg)
    return constrain(x, "batch", "seq", None)


def _encdec_dec_block_fwd(p: dict, x: Array, positions: Array, enc_out: Array,
                          cfg: ArchConfig) -> Array:
    h = attn.attend_train(p["attn"], rms_norm(x, p["ln1"]), positions, cfg,
                          causal=True, window=cfg.sliding_window)
    x = x + h
    h = attn.attend_train(p["xattn"], rms_norm(x, p["ln_x"]), positions, cfg,
                          causal=False, kv_input=enc_out, rope=False)
    x = x + h
    mp = p["mlp"]
    x = x + swiglu(rms_norm(x, p["ln2"]), mp["w_gate"], mp["w_up"],
                   mp["w_down"])
    return constrain(x, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Full forward (training) -> logits
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, batch: dict) -> Array:
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.jdtype)
    else:
        x = params["embed"][batch["tokens"]]
    return constrain(x, "batch", "seq", None)


def _encoder_forward(params, cfg: ArchConfig, enc_embeds: Array) -> Array:
    x = constrain(enc_embeds.astype(cfg.jdtype), "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, bp):
        x, _ = _dense_block_fwd(bp, x, positions, cfg, causal=False)
        return x, None

    x, _ = scan_layers(body, x, params["encoder"]["blocks"],
                       checkpoint=True)
    return rms_norm(x, params["encoder"]["final_norm"])


def forward(params, cfg: ArchConfig, batch: dict) -> tuple[Array, Array]:
    """Training/prefill forward. Returns (hidden (B,S,d), aux_loss)."""
    x = _embed(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    if cfg.family == "audio":
        enc_out = _encoder_forward(params, cfg, batch["enc_embeds"])

        def body(x, bp):
            return _encdec_dec_block_fwd(bp, x, positions, enc_out, cfg), None

        x, _ = scan_layers(body, x, params["blocks"], checkpoint=True)
        aux = jnp.float32(0.0)

    elif cfg.family == "hybrid":
        shared = params.get("shared_attn")
        every = cfg.attn_every or (cfg.num_layers + 1)

        def body(carry, inp):
            x = carry
            i, bp = inp
            x = _mamba_block_fwd(bp, x, cfg)
            if shared is not None:
                def with_attn(x):
                    y, _ = _dense_block_fwd(shared, x, positions, cfg)
                    return y
                x = jax.lax.cond((i + 1) % every == 0, with_attn,
                                 lambda x: x, x)
            return x, None

        idx = jnp.arange(cfg.num_layers)
        x, _ = scan_layers(body, x, (idx, params["blocks"]), checkpoint=True)
        aux = jnp.float32(0.0)

    elif cfg.family == "ssm":
        def body(x, bp):
            return _rwkv_block_fwd(bp, x, cfg), None

        x, _ = scan_layers(body, x, params["blocks"], checkpoint=True)
        aux = jnp.float32(0.0)

    else:  # dense / moe / vlm
        def body(carry, bp):
            x, aux = carry
            x, a = _dense_block_fwd(bp, x, positions, cfg)
            return (x, aux + a), None

        (x, aux), _ = scan_layers(body, (x, jnp.float32(0.0)),
                                  params["blocks"], checkpoint=True)

    return rms_norm(x, params["final_norm"]), aux


def logits_fn(params, cfg: ArchConfig, hidden: Array) -> Array:
    logits = hidden @ params["unembed"]
    logits = constrain(logits, "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]
    return logits


def lm_loss(params, cfg: ArchConfig, batch: dict,
            seq_weights: Optional[Array] = None) -> tuple[Array, dict]:
    """Next-token cross-entropy.

    ``seq_weights`` (B,) implements AMB's variable minibatch: per-sequence
    inclusion weights (0/1 mask from b_i(t)); the loss is the weighted mean
    over included sequences, so its gradient equals the paper's eq. (4)
    weighted consensus in the exact-averaging limit.
    """
    hidden, aux = forward(params, cfg, batch)
    logits = logits_fn(params, cfg, hidden).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    tok_nll = (logz - gold) * mask                       # (B, S)
    if seq_weights is not None:
        w = seq_weights[:, None].astype(jnp.float32)
        denom = jnp.maximum((mask * w).sum(), 1.0)
        loss = (tok_nll * w).sum() / denom
    else:
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = tok_nll.sum() / denom
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "ntok": denom}


# ---------------------------------------------------------------------------
# Prefill: forward + decode-ready caches
# ---------------------------------------------------------------------------

def _ring_from_linear(k: Array, cap: int) -> Array:
    """Arrange the last ``cap`` positions of (B, S, ...) into ring slots."""
    s = k.shape[1]
    if s <= cap:
        pad = cap - s
        return jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
    last = k[:, s - cap:]
    slots = (jnp.arange(s - cap, s)) % cap
    out = jnp.zeros((k.shape[0], cap) + k.shape[2:], k.dtype)
    return out.at[:, slots].set(last)


def prefill(params, cfg: ArchConfig, batch: dict, extra_capacity: int = 0,
            last_pos: Optional[Array] = None) -> tuple[Array, "DecodeState"]:
    """Process a full prompt; returns (last-token logits (B,V), DecodeState).

    The returned state is ready for ``decode_step`` at position S.  Attention
    caches are ring buffers of width ``sliding_window`` when SWA is active;
    linear caches get ``extra_capacity`` empty slots for subsequent decode.

    ``last_pos`` (scalar or (B,) int): per-request index of the final *real*
    prompt token, for prompts right-padded to a shared bucket length
    (heterogeneous prompt lengths in one fixed-shape batch — the serving
    tier's insert-on-prefill path).  Logits are gathered at each request's
    own last position instead of the padded batch's final column, and the
    returned state carries per-request positions ``last_pos + 1``, so decode
    resumes each request at its true depth; padded cache rows beyond it stay
    masked until decode overwrites them.  Causal attention keeps the real
    prefix's computation independent of the padding.
    """
    x = _embed(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window
    ring = window > 0
    cap = min(window, s) if ring else s
    enc_kv = None

    def pack(kv):
        k, v = kv
        if ring:
            k, v = _ring_from_linear(k, cap), _ring_from_linear(v, cap)
        elif extra_capacity:
            padw = ((0, 0), (0, extra_capacity), (0, 0), (0, 0))
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        return attn.KVCache(k, v, ring)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, bp):
            h, kv = attn.attend_train(
                bp["attn"], rms_norm(x, bp["ln1"]), positions, cfg,
                causal=True, window=window, return_kv=True)
            x = x + h
            x = constrain(x, "batch", "seq", None)
            if cfg.is_moe:
                h, _ = moe_mod.moe_forward(bp["moe"], rms_norm(x, bp["ln2"]),
                                           cfg)
            else:
                mp = bp["mlp"]
                h = swiglu(rms_norm(x, bp["ln2"]), mp["w_gate"], mp["w_up"],
                           mp["w_down"])
            return constrain(x + h, "batch", "seq", None), pack(kv)

        x, caches = scan_layers(body, x, params["blocks"])

    elif cfg.family == "ssm":
        def body(x, bp):
            xn = rms_norm(x, bp["ln1"])
            h, tmix = ssm_mod.rwkv6_forward(bp["tmix"], xn, cfg,
                                            return_state=True)
            x = x + h
            x = constrain(x, "batch", "seq", None)
            xn2 = rms_norm(x, bp["ln2"])
            cm = bp["cmix"]
            xp = jnp.pad(xn2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            k_in = xn2 * cm["mu"][0] + xp * (1 - cm["mu"][0])
            r_in = xn2 * cm["mu"][1] + xp * (1 - cm["mu"][1])
            v = jnp.square(jax.nn.relu(k_in @ cm["w_k"])) @ cm["w_v"]
            x = x + jax.nn.sigmoid(r_in @ cm["w_r"]) * v
            return (constrain(x, "batch", "seq", None),
                    {"tmix": tmix, "cmix_prev": xn2[:, -1]})

        x, caches = scan_layers(body, x, params["blocks"])

    elif cfg.family == "hybrid":
        shared = params.get("shared_attn")
        every = cfg.attn_every or (cfg.num_layers + 1)
        cap_eff = cap if ring else cap + extra_capacity
        zero_kv = (jnp.zeros((b, cap_eff, cfg.num_kv_heads, cfg.hd),
                             cfg.jdtype),) * 2

        def body(x, inp):
            i, bp = inp
            h, st = ssm_mod.mamba2_forward(
                bp["mamba"], rms_norm(x, bp["ln1"]), cfg, return_state=True)
            x = constrain(x + h, "batch", "seq", None)
            if shared is not None:
                def with_attn(x):
                    h, kv = attn.attend_train(
                        shared["attn"], rms_norm(x, shared["ln1"]),
                        positions, cfg, window=window, return_kv=True)
                    x = x + h
                    mp = shared["mlp"]
                    x = x + swiglu(rms_norm(x, shared["ln2"]), mp["w_gate"],
                                   mp["w_up"], mp["w_down"])
                    c = pack(kv)
                    return x, (c.k, c.v)
                def without(x):
                    return x, zero_kv
                x, kv = jax.lax.cond((i + 1) % every == 0, with_attn,
                                     without, x)
            else:
                kv = zero_kv
            return x, (st, kv)

        idx = jnp.arange(cfg.num_layers)
        x, (states, kvs) = scan_layers(body, x, (idx, params["blocks"]))
        napp = (cfg.num_layers // every) if shared is not None else 0
        attn_rows = [i for i in range(cfg.num_layers) if (i + 1) % every == 0]
        if napp:
            sel = jnp.asarray(attn_rows)
            caches = {"mamba": states,
                      "attn": attn.KVCache(kvs[0][sel], kvs[1][sel], ring)}
        else:
            caches = {"mamba": states,
                      "attn": attn.KVCache(kvs[0][:1], kvs[1][:1], ring)}

    elif cfg.family == "audio":
        enc_out = _encoder_forward(params, cfg, batch["enc_embeds"])

        def body(x, bp):
            h, kv = attn.attend_train(
                bp["attn"], rms_norm(x, bp["ln1"]), positions, cfg,
                causal=True, window=window, return_kv=True)
            x = x + h
            hx, xkv = attn.attend_train(
                bp["xattn"], rms_norm(x, bp["ln_x"]), positions, cfg,
                causal=False, kv_input=enc_out, rope=False, return_kv=True)
            x = x + hx
            mp = bp["mlp"]
            x = x + swiglu(rms_norm(x, bp["ln2"]), mp["w_gate"], mp["w_up"],
                           mp["w_down"])
            return constrain(x, "batch", "seq", None), (pack(kv), xkv)

        x, (caches, xkvs) = scan_layers(body, x, params["blocks"])
        enc_kv = xkvs
    else:
        raise ValueError(cfg.family)

    if last_pos is None:
        hidden = rms_norm(x[:, -1:], params["final_norm"])
        pos_out: Array = jnp.int32(s)
    else:
        sel = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(last_pos, jnp.int32), (-1,)), (b,))
        hidden = rms_norm(x[jnp.arange(b), sel][:, None], params["final_norm"])
        pos_out = sel + 1
    logits = (hidden @ params["unembed"])[:, 0]
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]
    logits = constrain(logits, "batch", "vocab")
    return logits, DecodeState(caches, pos_out, enc_kv)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class DecodeState:
    """Per-model decode state: per-layer caches/SSM states + position."""

    def __init__(self, caches, pos, enc_kv=None):
        self.caches, self.pos, self.enc_kv = caches, pos, enc_kv

    def tree_flatten(self):
        return (self.caches, self.pos, self.enc_kv), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      per_slot_pos: bool = False) -> DecodeState:
    """Allocate decode state for a context of ``cache_len`` tokens.

    Attention caches are ring buffers of size ``sliding_window`` when SWA is
    on (O(window) memory at 500k context), else linear of size cache_len.

    ``per_slot_pos`` allocates a (batch,) position vector instead of a shared
    scalar, so each batch row decodes at its own depth — the serving tier's
    slot array, where rows are independent requests inserted at different
    times.
    """
    l = cfg.num_layers
    ring = cfg.sliding_window > 0
    cap = min(cfg.sliding_window, cache_len) if ring else cache_len

    def stack(make_one):
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[make_one() for _ in range(l)])

    enc_kv = None
    if cfg.family in ("dense", "moe", "vlm"):
        caches = stack(lambda: attn.init_cache(cfg, batch, cap, ring=ring))
    elif cfg.family == "ssm":
        caches = stack(lambda: {
            "tmix": ssm_mod.rwkv6_init_state(cfg, batch),
            "cmix_prev": jnp.zeros((batch, cfg.d_model), cfg.jdtype)})
    elif cfg.family == "hybrid":
        napp = (cfg.num_layers // cfg.attn_every) if cfg.attn_every else 0
        caches = {
            "mamba": stack(lambda: ssm_mod.mamba2_init_state(cfg, batch)),
            "attn": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[attn.init_cache(cfg, batch, cap, ring=ring)
                  for _ in range(max(napp, 1))]),
        }
    elif cfg.family == "audio":
        caches = stack(lambda: attn.init_cache(cfg, batch, cap, ring=ring))
        enc = cfg.encoder_seq or 1500
        kvshape = (l, batch, enc, cfg.num_kv_heads, cfg.hd)
        enc_kv = (jnp.zeros(kvshape, cfg.jdtype), jnp.zeros(kvshape, cfg.jdtype))
    else:
        raise ValueError(cfg.family)
    pos = (jnp.zeros((batch,), jnp.int32) if per_slot_pos
           else jnp.zeros((), jnp.int32))
    return DecodeState(caches, pos, enc_kv)


def insert_decode_state(state: DecodeState, one: DecodeState,
                        slot: Array) -> DecodeState:
    """Write a batch-1 ``DecodeState`` (from ``prefill``) into row ``slot``.

    Every cache leaf across all families is (L, B, ...) with batch at axis 1,
    so a single axis-1 dynamic_update_slice serves dense, moe, ssm, hybrid
    and audio alike — and ``slot`` being a traced scalar means one jitted
    insert handles every request without recompilation.  The full cap extent
    of the slot is overwritten (no stale K/V leaks from the previous tenant);
    ``one``'s caches must therefore match the slot array's capacity (prefill
    with ``extra_capacity = cap - prompt_len``).  ``state.pos`` must be the
    per-slot (B,) form from ``init_decode_state(per_slot_pos=True)``.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def put(big, small):
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=1)

    caches = jax.tree.map(put, state.caches, one.caches)
    pos1 = jnp.reshape(one.pos, (-1,))[:1].astype(state.pos.dtype)
    pos = jax.lax.dynamic_update_slice(state.pos, pos1, (slot,))
    enc_kv = state.enc_kv
    if enc_kv is not None:
        enc_kv = jax.tree.map(put, enc_kv, one.enc_kv)
    return DecodeState(caches, pos, enc_kv)


def evict_decode_state(state: DecodeState, slot: Array) -> DecodeState:
    """Zero row ``slot``'s caches and position (slot-reuse hygiene).

    Functionally optional — ``insert_decode_state`` overwrites the whole
    extent — but zeroing on retire means a leaked slot holds no residual
    prompt data and masks any engine bug as an obvious all-zeros cache
    rather than a stale cross-request one.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def clear(big):
        row = jax.lax.dynamic_slice_in_dim(big, slot, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(
            big, jnp.zeros_like(row), slot, axis=1)

    caches = jax.tree.map(clear, state.caches)
    pos = jax.lax.dynamic_update_slice(
        state.pos, jnp.zeros((1,), state.pos.dtype), (slot,))
    enc_kv = state.enc_kv
    if enc_kv is not None:
        enc_kv = jax.tree.map(clear, enc_kv)
    return DecodeState(caches, pos, enc_kv)


def decode_step(params, cfg: ArchConfig, state: DecodeState,
                token: Array) -> tuple[Array, DecodeState]:
    """One-token decode. token: (B,) int32 -> logits (B, V)."""
    x = params["embed"][token][:, None, :]               # (B,1,d)
    x = constrain(x, "batch", None, None)
    pos = state.pos
    w = cfg.sliding_window

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, inp):
            bp, cache = inp
            h, new_cache = attn.decode_attend(
                bp["attn"], rms_norm(x, bp["ln1"]), pos, cache, cfg, window=w)
            x = x + h
            if cfg.is_moe:
                h, _ = moe_mod.moe_forward(bp["moe"], rms_norm(x, bp["ln2"]),
                                           cfg)
            else:
                mp = bp["mlp"]
                h = swiglu(rms_norm(x, bp["ln2"]), mp["w_gate"], mp["w_up"],
                           mp["w_down"])
            return x + h, new_cache

        x, new_caches = scan_layers(body, x, (params["blocks"], state.caches))

    elif cfg.family == "ssm":
        def body(x, inp):
            bp, st = inp
            h, tmix_new = ssm_mod.rwkv6_decode(
                bp["tmix"], rms_norm(x, bp["ln1"]), st["tmix"], cfg)
            x = x + h
            xn = rms_norm(x, bp["ln2"])
            cm = bp["cmix"]
            xp = st["cmix_prev"][:, None, :]
            k_in = xn * cm["mu"][0] + xp * (1 - cm["mu"][0])
            r_in = xn * cm["mu"][1] + xp * (1 - cm["mu"][1])
            v = jnp.square(jax.nn.relu(k_in @ cm["w_k"])) @ cm["w_v"]
            x = x + jax.nn.sigmoid(r_in @ cm["w_r"]) * v
            return x, {"tmix": tmix_new, "cmix_prev": xn[:, 0]}

        x, new_caches = scan_layers(body, x, (params["blocks"], state.caches))

    elif cfg.family == "hybrid":
        shared = params.get("shared_attn")
        every = cfg.attn_every or (cfg.num_layers + 1)
        mamba_states = state.caches["mamba"]
        attn_caches = state.caches["attn"]
        new_mamba, new_attn = [], []
        app = 0
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
            st = jax.tree.map(lambda t, i=i: t[i], mamba_states)
            h, st_new = ssm_mod.mamba2_decode(
                bp["mamba"], rms_norm(x, bp["ln1"]), st, cfg)
            x = x + h
            new_mamba.append(st_new)
            if shared is not None and (i + 1) % every == 0:
                cache = jax.tree.map(lambda t, a=app: t[a], attn_caches)
                h, cache_new = attn.decode_attend(
                    shared["attn"], rms_norm(x, shared["ln1"]), pos, cache,
                    cfg, window=w)
                x = x + h
                mp = shared["mlp"]
                x = x + swiglu(rms_norm(x, shared["ln2"]), mp["w_gate"],
                               mp["w_up"], mp["w_down"])
                new_attn.append(cache_new)
                app += 1
        new_caches = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
            "attn": (jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn)
                     if new_attn else attn_caches),
        }

    elif cfg.family == "audio":
        enc_k, enc_v = state.enc_kv

        def body(x, inp):
            bp, cache, ek, ev = inp
            h, new_cache = attn.decode_attend(
                bp["attn"], rms_norm(x, bp["ln1"]), pos, cache, cfg, window=w)
            x = x + h
            h, _ = attn.decode_attend(
                bp["xattn"], rms_norm(x, bp["ln_x"]), pos, cache, cfg,
                cross_kv=(ek, ev))
            x = x + h
            mp = bp["mlp"]
            x = x + swiglu(rms_norm(x, bp["ln2"]), mp["w_gate"], mp["w_up"],
                           mp["w_down"])
            return x, new_cache

        x, new_caches = scan_layers(
            body, x, (params["blocks"], state.caches, enc_k, enc_v))
    else:
        raise ValueError(cfg.family)

    hidden = rms_norm(x, params["final_norm"])           # (B,1,d)
    logits = (hidden @ params["unembed"])[:, 0]
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]
    logits = constrain(logits, "batch", "vocab")
    return logits, DecodeState(new_caches, pos + 1, state.enc_kv)
