"""Top-k mixture-of-experts layer with grouped, capacity-bounded dispatch.

Dispatch is the GShard/Switch *grouped* pattern adapted for TPU expert
parallelism: tokens are dispatched **locally per sequence** (the group) —
argsort by expert id within the sequence, rank-within-expert, scatter into a
per-group (E, C_g, d) buffer.  The buffer's group dim is batch-sharded
("data") and its expert dim is expert-sharded ("model"), so XLA lowers the
group->expert exchange to the canonical all-to-all between the two mesh
axes.

Why grouped: a *global* argsort over (global_batch x seq x k) token
assignments is unshardable — the SPMD partitioner replicates the entire
dispatch computation on every chip (measured: 64 GiB f32 gathers per chip
per layer on qwen3-moe train_4k; EXPERIMENTS.md §Perf iteration 2).  Local
per-sequence sort keeps every dispatch tensor at per-chip shapes and is the
standard production choice; the cost is per-group capacity (more drops under
cross-sequence imbalance), covered by `capacity_factor`.

The router's load-balance auxiliary loss participates in the same AMB
weighted gradient consensus as the main loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .common import ArchConfig, init_linear

Array = jax.Array


def moe_params(key: Array, cfg: ArchConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": init_linear(ks[0], (d, e), jnp.float32),
        "w_gate": init_linear(ks[1], (e, d, ff), cfg.jdtype),
        "w_up": init_linear(ks[2], (e, d, ff), cfg.jdtype),
        "w_down": init_linear(ks[3], (e, ff, d), cfg.jdtype),
    }


def _dispatch_group(xg: Array, idx: Array, keep_dtype, e: int, k: int,
                    cap: int):
    """Per-group dispatch: xg (S, d), idx (S, k) -> buf (e, cap, d) + meta."""
    s = xg.shape[0]
    flat_e = idx.reshape(-1)                                   # (S*k,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    arange = jnp.arange(s * k)
    seg_start = jnp.full((e,), s * k, jnp.int32).at[sorted_e].min(
        arange.astype(jnp.int32), mode="drop")
    rank = arange - seg_start[sorted_e]                        # (S*k,)
    keep = rank < cap
    token_of = order // k
    slot_of = order % k

    buf = jnp.zeros((e, cap, xg.shape[-1]), keep_dtype)
    buf = buf.at[sorted_e, jnp.where(keep, rank, 0)].add(
        jnp.where(keep[:, None], xg[token_of], 0.0).astype(keep_dtype),
        mode="drop")
    return buf, (sorted_e, rank, keep, token_of, slot_of)


def _combine_group(y: Array, gate: Array, meta, s: int) -> Array:
    """Per-group combine: y (e, cap, d) -> (S, d)."""
    sorted_e, rank, keep, token_of, slot_of = meta
    gathered = y[sorted_e, jnp.where(keep, rank, 0)]           # (S*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = gate[token_of, slot_of][:, None].astype(y.dtype)       # (S*k, 1)
    return jnp.zeros((s, y.shape[-1]), y.dtype).at[token_of].add(
        gathered * w)


def moe_forward(p: dict, x: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balance loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"])                           # (B, S, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): e * sum_e f_e * p_e, global stats
    me = probs.mean((0, 1))                                    # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    # --- grouped local dispatch ---
    # Group size: per-sequence for training/prefill; for decode (s=1) a
    # per-sequence group would allocate e*cap buffer slots for only k
    # assignments (measured 16x padded-compute waste on decode_32k), so
    # groups coarsen to >=64 tokens while staying aligned with the "data"
    # batch shards (G divides B, groups never straddle shard boundaries).
    tokens = b * s
    groups = b
    while groups > 16 and tokens // groups < 64 and groups % 2 == 0:
        groups //= 2
    if tokens // groups < 64:
        # Decode scale (e.g. B=128, S=1): grouped dispatch would pit the
        # group dim and the expert weights' FSDP dim against each other on
        # "data" and force per-token expert-weight all-gathers (measured
        # collective 0.0086 -> 0.15 s).  A single replicated-dispatch
        # group over so few tokens is cheap and lets the expert einsum
        # partial-sum against the weights' sharding — the global-dispatch
        # behaviour, which is only pathological at training scale.
        groups = 1
    tg = tokens // groups                                      # tokens/group
    xg = x.reshape(groups, tg, d)
    idx_g = idx.reshape(groups, tg, k)

    cap = int(max(1, round(cfg.capacity_factor * tg * k / e)))
    buf, meta = jax.vmap(
        lambda xgi, igi: _dispatch_group(xgi, igi, x.dtype, e, k, cap)
    )(xg, idx_g)                                               # (G, e, cap, d)
    if groups > 1:
        # group dim on "data", expert dim on "model": the constraint makes
        # XLA emit the group->expert all-to-all here (and its inverse at
        # combine).  At groups == 1 (decode) leave the layout free: pinning
        # it blocks the partitioner's partial-sum strategy against the
        # FSDP-sharded expert weights and forces weight all-gathers.
        buf = constrain(buf, "batch", "expert", None, None)

    # expert computation, batched over groups x experts (MXU f32 accum on
    # TPU; plain bf16 dots on CPU-executed smoke configs)
    acc = cfg.acc_dtype()

    def ein(sub, a, b_):
        if acc is not None:
            return jnp.einsum(sub, a, b_, preferred_element_type=acc)
        return jnp.einsum(sub, a, b_)
    g = jax.nn.silu(ein("becd,edf->becf", buf, p["w_gate"]))
    u = ein("becd,edf->becf", buf, p["w_up"])
    y = ein("becf,efd->becd", (g * u).astype(buf.dtype),
            p["w_down"]).astype(x.dtype)
    # NOTE (§Perf iteration 3, REFUTED): explicitly re-laying y out to
    # group-local (P(batch, None, ...)) before the combine gather was
    # predicted to replace the partitioner's f32 (B, S*k, d) all-gathers
    # with one bf16 all-to-all; measured collective went UP 30.0 -> 37.3 s
    # (the partitioner's own choice CSEs the re-layout with the backward).
    # Keep the expert-sharded layout and let SPMD place the exchange.
    if groups > 1:
        y = constrain(y, "batch", "expert", None, None)

    gate_g = gate.reshape(groups, tg, k)
    out = jax.vmap(lambda yg, gg, mt: _combine_group(yg, gg, mt, tg)
                   )(y, gate_g, meta)                          # (G, tg, d)
    out = out.reshape(b, s, d)
    return constrain(out, "batch", None, None), aux
