"""GQA attention: chunked-flash training/prefill + KV-cache decode.

Pure-JAX online-softmax (flash) attention so that 32k prefill and 4k training
lower without materialising (S, S) score matrices.  The Pallas TPU kernel in
``repro/kernels/flash_attention`` implements the same contraction for the MXU;
``repro.kernels.ops.flash_attention`` routes to it on TPU and to this
reference on CPU.

Supports: grouped-query attention, qk RMS-norm (qwen3), QKV bias (qwen2),
sliding-window masking (long-context variant), cross-attention (whisper), and
ring-buffer KV caches for O(window) long-context decode.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import (ArchConfig, apply_rope, init_linear, rms_norm,
                     scan_or_unroll)

Array = jax.Array
NEG_INF = -1e30


def attention_params(key: Array, cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], (d, h * hd), cfg.jdtype),
        "wk": init_linear(ks[1], (d, kv * hd), cfg.jdtype),
        "wv": init_linear(ks[2], (d, kv * hd), cfg.jdtype),
        "wo": init_linear(ks[3], (h * hd, d), cfg.jdtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), cfg.jdtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.jdtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.jdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p: dict, x: Array, cfg: ArchConfig,
                 kv_input: Optional[Array] = None):
    """Returns q (B,S,KV,G,hd), k,v (B,Skv,KV,hd)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    g = h // kv
    xkv = x if kv_input is None else kv_input
    skv = xkv.shape[1]
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, kv, g, hd)
    k = k.reshape(b, skv, kv, hd)
    v = v.reshape(b, skv, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    window: int, q_offset: Array | int,
                    kv_valid: Optional[Array] = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    accum_dtype=None) -> Array:
    """Online-softmax attention.

    q: (B, Sq, KV, G, hd); k, v: (B, Skv, KV, hd).
    q_offset: absolute position of q[.., 0] (for causal masking vs cache).
    kv_valid: optional (B, Skv) bool — which cache slots hold real tokens.
    accum_dtype: f32 -> MXU-native bf16-in/f32-accum dots (TPU); None ->
      upcast to f32 before the dots (CPU-executable, same numerics).
    Returns (B, Sq, KV, G, hd).
    """
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq = -(-sq // qc)
    nk = -(-skv // kc)
    pad_q = nq * qc - sq
    pad_k = nk * kc - skv

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    valid = jnp.ones((b, skv), bool) if kv_valid is None else kv_valid
    valid = jnp.pad(valid, ((0, 0), (0, pad_k)))

    q_pos = jnp.asarray(q_offset) + jnp.arange(nq * qc)          # (Sq',)
    k_pos = jnp.arange(nk * kc)                                   # (Skv',)

    qp = qp.reshape(b, nq, qc, kvh, g, hd)

    def q_block(carry, qi):
        qb = qp[:, qi]                                            # (B,qc,KV,G,hd)
        qpos_b = jax.lax.dynamic_slice_in_dim(q_pos, qi * qc, qc)

        def kv_block(state, ki):
            m, l, acc = state
            kb = jax.lax.dynamic_slice_in_dim(kp, ki * kc, kc, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, ki * kc, kc, axis=1)
            vld = jax.lax.dynamic_slice_in_dim(valid, ki * kc, kc, axis=1)
            kpos_b = jax.lax.dynamic_slice_in_dim(k_pos, ki * kc, kc)
            # MXU-native: bf16 inputs, f32 accumulation — avoids
            # materialising f32 copies of Q/K (and their convert chains)
            # while keeping f32 softmax numerics (s_ itself is f32).
            if accum_dtype is not None:
                s_ = jnp.einsum("bqkgh,bckh->bqgkc", qb, kb,
                                preferred_element_type=accum_dtype) * scale
            else:
                s_ = jnp.einsum("bqkgh,bckh->bqgkc",
                                qb.astype(jnp.float32),
                                kb.astype(jnp.float32)) * scale
            # s_: (B,qc,G,KV,kc) f32
            mask = vld[:, None, None, None, :]
            if causal:
                mask = mask & (kpos_b[None, None, None, None, :]
                               <= qpos_b[None, :, None, None, None])
            if window > 0:
                mask = mask & (qpos_b[None, :, None, None, None]
                               - kpos_b[None, None, None, None, :] < window)
            s_ = jnp.where(mask, s_, NEG_INF)
            m_new = jnp.maximum(m, s_.max(-1))
            p_ = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(-1)
            # P·V on the MXU in bf16 (the standard flash-kernel choice);
            # the accumulator stays f32.
            if accum_dtype is not None:
                pv = jnp.einsum("bqgkc,bckh->bqgkh", p_.astype(vb.dtype),
                                vb, preferred_element_type=accum_dtype)
            else:
                pv = jnp.einsum("bqgkc,bckh->bqgkh", p_,
                                vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, qc, g, kvh), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qc, g, kvh), jnp.float32)
        a0 = jnp.zeros((b, qc, g, kvh, hd), jnp.float32)
        (m, l, acc), _ = scan_or_unroll(kv_block, (m0, l0, a0), nk)
        out = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,qc,G,KV,hd)
        return carry, out.transpose(0, 1, 3, 2, 4)                # (B,qc,KV,G,hd)

    _, outs = scan_or_unroll(q_block, None, nq)                   # (nq,B,qc,...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * qc, kvh, g, hd)
    return out[:, :sq].astype(q.dtype)


def attend_train(p: dict, x: Array, positions: Array, cfg: ArchConfig, *,
                 causal: bool = True, window: int = 0,
                 kv_input: Optional[Array] = None,
                 rope: bool = True, return_kv: bool = False):
    """Full-sequence attention (training / prefill / encoder).

    With ``return_kv`` also returns the (roped) k, v — the decode cache
    contents after a prefill of this sequence.
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, cfg, kv_input)
    if rope:
        kv_pos = positions if kv_input is None else jnp.arange(k.shape[1])
        qr = q.reshape(b, s, -1, cfg.hd)
        qr = apply_rope(qr, positions, cfg.rope_theta)
        q = qr.reshape(q.shape)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    # NOTE (§Perf iteration 4, REFUTED): explicitly constraining K/V to a
    # seq-replicated layout here (one bf16 all-gather per layer instead of
    # ~104 per-chunk f32 gathers) made the partitioner REPLICATE the whole
    # attention computation over "model" (flops 3.2x, memory 22.5 -> 55 s).
    # Keep K/V in the partitioner-chosen layout.
    out = flash_attention(
        q, k, v, causal=causal, window=window,
        q_offset=0, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        accum_dtype=cfg.acc_dtype())
    out = out.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class KVCache:
    """Per-layer KV cache; ``ring`` (static) selects ring-buffer layout."""

    def __init__(self, k: Array, v: Array, ring: bool):
        self.k, self.v, self.ring = k, v, bool(ring)

    def tree_flatten(self):
        return (self.k, self.v), self.ring

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def init_cache(cfg: ArchConfig, batch: int, capacity: int, *,
               ring: bool) -> KVCache:
    shape = (batch, capacity, cfg.num_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cfg.jdtype), jnp.zeros(shape, cfg.jdtype),
                   ring)


def decode_attend(p: dict, x: Array, pos: Array, cache: KVCache,
                  cfg: ArchConfig, *, window: int = 0,
                  cross_kv: Optional[tuple[Array, Array]] = None,
                  cross_len: int = 0) -> tuple[Array, KVCache]:
    """One-token decode.  x: (B, 1, d); pos: current position.

    ``pos`` is either a scalar (every request at the same position — the
    static-batch path) or a ``(B,)`` vector of per-request positions (the
    continuous-batching slot path: each slot decodes at its own depth,
    writes its own cache row, and masks its own validity window).

    With ``cross_kv`` set this is cross-attention against a precomputed
    encoder KV (whisper); the cache is untouched.
    """
    b, s, d = x.shape
    assert s == 1
    kvh, hd = cfg.num_kv_heads, cfg.hd
    g = cfg.num_heads // kvh
    if cross_kv is not None:
        q = (x @ p["wq"]).reshape(b, 1, kvh, g, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
        k, v = cross_kv
        scores = jnp.einsum("bqkgh,bckh->bqgkc", q.astype(jnp.float32),
                            k.astype(jnp.float32)) / jnp.sqrt(hd)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bqgkc,bckh->bqgkh", probs, v.astype(jnp.float32))
        out = out.transpose(0, 1, 3, 2, 4).reshape(b, 1, -1).astype(x.dtype)
        return out @ p["wo"], cache

    q, k, v = _project_qkv(p, x, cfg)
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1               # (B,) per-request positions
    posq = pos.reshape(-1, 1)              # (B, 1) per-slot or (1, 1) shared
    qr = apply_rope(q.reshape(b, 1, -1, hd), posq, cfg.rope_theta)
    q = qr.reshape(q.shape)
    k = apply_rope(k, posq, cfg.rope_theta)

    cap = cache.k.shape[1]
    slot = pos % cap if cache.ring else pos
    if per_slot:
        # each request writes its own row: a batched scatter, not a slice
        row = jnp.clip(slot, 0, cap - 1)
        k_all = cache.k.at[jnp.arange(b), row].set(k[:, 0])
        v_all = cache.v.at[jnp.arange(b), row].set(v[:, 0])
    else:
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_cache = KVCache(k_all, v_all, cache.ring)

    idx = jnp.arange(cap)[None, :]         # broadcasts against posq (B|1, 1)
    if cache.ring:
        # slot i holds absolute position: the largest p <= pos with p % cap == i
        abs_pos = posq - ((posq - idx) % cap)
        valid = (abs_pos >= 0) & (abs_pos <= posq)
        if window > 0:
            valid &= (posq - abs_pos) < window
    else:
        valid = idx <= posq
        if window > 0:
            valid &= (posq - idx) < window
    scores = jnp.einsum("bqkgh,bckh->bqgkc", q.astype(jnp.float32),
                        k_all.astype(jnp.float32)) / jnp.sqrt(hd)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqgkc,bckh->bqgkh", probs, v_all.astype(jnp.float32))
    out = out.transpose(0, 1, 3, 2, 4).reshape(b, 1, -1).astype(x.dtype)
    return out @ p["wo"], new_cache
