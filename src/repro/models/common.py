"""Architecture configs and shared building blocks for the model zoo."""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# Loop unrolling switch.  lax.scan keeps HLO O(1) in trip count — which also
# means XLA cost_analysis counts scan bodies ONCE.  The dry-run's
# cost-measurement compiles run under ``unrolled_loops()`` so every layer and
# every attention/SSM block iteration appears in the HLO explicitly.
# --------------------------------------------------------------------------

_UNROLL = threading.local()


def unroll_active() -> bool:
    return getattr(_UNROLL, "on", False)


@contextlib.contextmanager
def unrolled_loops(enable: bool = True):
    old = getattr(_UNROLL, "on", False)
    _UNROLL.on = enable
    try:
        yield
    finally:
        _UNROLL.on = old


def scan_or_unroll(body, carry, xs, *, checkpoint: bool = False):
    """lax.scan, or an unrolled python loop under ``unrolled_loops()``.

    ``xs`` may be a pytree of stacked inputs or an integer length (bodies
    that index closures by iteration count).
    """
    fn = jax.checkpoint(body) if checkpoint else body
    if isinstance(xs, int):
        length, get = xs, lambda i: i
        xs_arr = jnp.arange(xs)
    else:
        length = jax.tree.leaves(xs)[0].shape[0]
        get = lambda i: jax.tree.map(lambda t: t[i], xs)
        xs_arr = xs
    if not unroll_active():
        if isinstance(xs, int):
            return jax.lax.scan(fn, carry, xs_arr)
        return jax.lax.scan(fn, carry, xs)
    ys = []
    for i in range(length):
        carry, y = fn(carry, get(i))
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (full or reduced/smoke variant)."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: int = 0         # 0 = full attention; >0 = SWA width
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256            # chunk length for SSM/RWKV scans
    vocab_pad_to: int = 0           # pad embed/unembed vocab dim to this
                                    # (0 = off) so it shards on the model axis
    head_pad_to: int = 0            # pad recurrent heads to this count so the
                                    # per-head state shards head-aligned on the
                                    # production model axis (rwkv6: 40 -> 48 on
                                    # a 16-way axis; 0 = off).  Numerically
                                    # exact: padded channels have r=k=v=0.
    attn_every: int = 0             # hybrid: shared attn block every k layers
    # encoder-decoder (audio)
    encoder_layers: int = 0
    encoder_seq: int = 0            # stub frontend frames (whisper: 1500)
    # input modality
    input_mode: str = "tokens"      # tokens | embeds (vlm/audio frontends stubbed)
    # numerics
    dtype: str = "bfloat16"
    # MXU accumulation policy: bf16-input dots accumulate in f32
    # (preferred_element_type).  TPU-native; the XLA *CPU* runtime cannot
    # execute BF16xBF16=F32 dots (compile is fine), so smoke configs — the
    # only ones executed on CPU — turn it off.  Full configs keep it on:
    # they are only lowered/compiled (dry-run) or run on real TPUs.
    mxu_f32_accum: bool = True
    # attention compute chunking (pure-JAX flash).  (1024, 1024) is the
    # largest VMEM-valid flash tile (4 MiB f32 scores/block, double-
    # buffered) and measured best on the train_4k roofline among valid
    # points (§Perf iteration 5): fewer q-blocks => fewer per-chunk KV
    # re-gathers and fewer score-chain materialisations.
    q_chunk: int = 1024
    kv_chunk: int = 1024

    def acc_dtype(self):
        """preferred_element_type for bf16 matmuls (None = input dtype)."""
        import jax.numpy as _jnp
        return _jnp.float32 if self.mxu_f32_accum else None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Vocab size of the embed/unembed *parameters*.

        Padding a mesh-indivisible vocab (whisper: 51865 on a 16-way model
        axis) keeps embed/unembed shardable instead of replicated — which
        otherwise costs a full unembed read per decoded token (measured
        ~106 MiB/token; §Perf hillclimb 3).  Logits are sliced back to
        ``vocab_size``; padded ids are never produced.
        """
        return max(self.vocab_pad_to, self.vocab_size)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
        if self.family == "ssm":      # rwkv6: attention-free
            attn = 0
        if self.is_moe:
            mlp = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per_layer = 4 * d * d + 2 * d * 64 + 3 * d * self.d_ff + 2 * d
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state) + d_in * d + 2 * d
        emb = self.vocab_size * d * 2   # embed + unembed (untied)
        total = self.num_layers * per_layer + emb
        if self.is_encdec:
            total += self.encoder_layers * (attn + mlp + 2 * d)
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * self.d_ff   # one shared block
        return int(total)


# ---------------------------------------------------------------------------
# Primitive layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def init_linear(key: Array, shape, dtype, scale: Optional[float] = None) -> Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)
