"""CSV/JSONL run metrics — tiny, dependency-free."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any


class MetricsLogger:
    """Append-only JSONL logger with wall-clock stamps."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._t0 = time.time()
        self._fh = self.path.open("a")

    def log(self, step: int, **metrics: Any) -> None:
        rec = {"step": step, "elapsed_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            rec[k] = float(v) if hasattr(v, "item") or isinstance(
                v, (int, float)) else v
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self):
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def read_metrics(path: str | Path) -> list[dict]:
    return [json.loads(line) for line in Path(path).read_text().splitlines()
            if line.strip()]
