from .optimizers import AdamW, DualAveragingOpt, Optimizer, Sgd, make_optimizer

__all__ = ["AdamW", "DualAveragingOpt", "Optimizer", "Sgd", "make_optimizer"]
