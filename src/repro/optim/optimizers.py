"""Pytree optimizers: the paper's dual averaging + AdamW/SGD baselines.

Dual averaging for deep networks generalises the paper's eq. (7) with
``h(w) = ||w - w(1)||^2`` (1-strongly convex, argmin = init — consistent with
eq. 2's ``w(1) = argmin h``), giving the closed-form prox

    w(t+1) = w(1) - z(t+1) / (2 beta(t+1)).

For convex problems with ``w(1) = 0`` this is exactly the paper's update.
The prox is fused into a single Pallas kernel on TPU
(``repro.kernels.ops.dual_update``); here it routes through the same op.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.dual_averaging import BetaSchedule

Array = jax.Array
PyTree = Any


class Optimizer:
    def init(self, params: PyTree) -> PyTree:
        raise NotImplementedError

    def apply(self, grads: PyTree, state: PyTree,
              params: PyTree) -> tuple[PyTree, PyTree]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class DualAveragingOpt(Optimizer):
    beta: BetaSchedule = BetaSchedule(k=100.0, mu=1.0, scale=100.0)
    radius: Optional[float] = None    # optional L2 ball around init

    def init(self, params):
        return {
            "z": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "w0": jax.tree.map(lambda p: p.astype(jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def apply(self, grads, state, params):
        from ..kernels import ops as kops
        t_new = state["t"] + 1
        beta = self.beta(t_new.astype(jnp.float32) + 1.0)
        z_new = jax.tree.map(
            lambda z, g: z + g.astype(jnp.float32), state["z"], grads)
        def prox(z, w0, p):
            w = kops.dual_update(z, w0, beta, self.radius)
            return w.astype(p.dtype)
        new_params = jax.tree.map(prox, z_new, state["w0"], params)
        return new_params, {"z": z_new, "w0": state["w0"], "t": t_new}


@dataclasses.dataclass(frozen=True)
class AdamW(Optimizer):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def apply(self, grads, state, params):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** tf
        c2 = 1.0 - self.b2 ** tf

        def upd(m, v, g, p):
            g = g.astype(jnp.float32)
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * g * g
            step = (m_new / c1) / (jnp.sqrt(v_new / c2) + self.eps)
            p_new = p.astype(jnp.float32) - self.lr * (
                step + self.weight_decay * p.astype(jnp.float32))
            return m_new, v_new, p_new.astype(p.dtype)

        out = jax.tree.map(upd, state["m"], state["v"], grads, params)
        m_new = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        p_new = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return p_new, {"m": m_new, "v": v_new, "t": t}


@dataclasses.dataclass(frozen=True)
class Sgd(Optimizer):
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if self.momentum:
            return {"v": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)}
        return {}

    def apply(self, grads, state, params):
        if self.momentum:
            v_new = jax.tree.map(
                lambda v, g: self.momentum * v + g.astype(jnp.float32),
                state["v"], grads)
            p_new = jax.tree.map(
                lambda p, v: (p.astype(jnp.float32) - self.lr * v
                              ).astype(p.dtype), params, v_new)
            return p_new, {"v": v_new}
        p_new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return p_new, state


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"dual_averaging": DualAveragingOpt, "adamw": AdamW,
            "sgd": Sgd}[name](**kw)
