"""Streaming synthetic data sources (the paper's workloads are *online*).

Every stream is deterministic in (seed, node, epoch, index) — the property
the AMB engine relies on so that node i's s-th sample of epoch t is the same
regardless of how many samples other nodes processed (i.i.d. from Q, paper
§3).  Streams generate on demand; nothing is materialised up front.

  * LinRegStream — §6.1: x ~ N(0, I_d), y = x.w* + N(0, 1e-3).
  * LogRegStream — §6.2 stand-in: 10-class Gaussian mixture, 784-dim
    ("MNIST-like"; MNIST itself is not available offline — DESIGN.md §7).
  * LMTokenStream — token sequences from a fixed-transition synthetic
    grammar, for LM training examples (b).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LinRegStream:
    dim: int
    seed: int = 0
    noise_var: float = 1e-3

    def w_star(self) -> Array:
        return jax.random.normal(jax.random.PRNGKey(self.seed ^ 0x5757),
                                 (self.dim,), jnp.float32)

    def batch(self, node: int, epoch: int, size: int,
              w_star: Optional[Array] = None):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), node), epoch)
        kx, kn = jax.random.split(key)
        x = jax.random.normal(kx, (size, self.dim), jnp.float32)
        ws = self.w_star() if w_star is None else w_star
        y = x @ ws + jnp.sqrt(self.noise_var) * jax.random.normal(
            kn, (size,), jnp.float32)
        return x, y


@dataclasses.dataclass(frozen=True)
class LogRegStream:
    dim: int = 784
    num_classes: int = 10
    seed: int = 0
    spread: float = 2.0

    def class_means(self) -> Array:
        return self.spread * jax.random.normal(
            jax.random.PRNGKey(self.seed ^ 0xC1A5), (self.num_classes, self.dim),
            jnp.float32) / jnp.sqrt(self.dim)

    def batch(self, node: int, epoch: int, size: int):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), node), epoch)
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, (size,), 0, self.num_classes)
        x = self.class_means()[y] + jax.random.normal(
            kx, (size, self.dim), jnp.float32)
        return x, y


@dataclasses.dataclass(frozen=True)
class LMTokenStream:
    """Synthetic token grammar: order-1 Markov chain with a planted
    block-diagonal transition structure (learnable, non-trivial entropy)."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    num_blocks: int = 16

    def _transition_logits(self) -> Array:
        v = self.vocab_size
        key = jax.random.PRNGKey(self.seed ^ 0x70CE)
        base = jax.random.normal(key, (v, v), jnp.float32) * 0.5
        blk = v // self.num_blocks or 1
        same = (jnp.arange(v)[:, None] // blk) == (jnp.arange(v)[None] // blk)
        return base + 2.0 * same

    def batch(self, node: int, epoch: int, size: int) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), node), epoch)
        logits = self._transition_logits()

        def seq(k):
            k0, ks = jax.random.split(k)
            first = jax.random.randint(k0, (), 0, self.vocab_size)

            def step(tok, kk):
                nxt = jax.random.categorical(kk, logits[tok])
                return nxt, nxt

            _, rest = jax.lax.scan(step, first,
                                   jax.random.split(ks, self.seq_len - 1))
            return jnp.concatenate([first[None], rest])

        toks = jax.vmap(seq)(jax.random.split(key, size))
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.full((size, 1), -1, toks.dtype)], axis=1)
        return {"tokens": toks, "labels": labels}


def make_stream(kind: str, **kw):
    return {"linreg": LinRegStream, "logreg": LogRegStream,
            "lm": LMTokenStream}[kind](**kw)


def shard_batch(batch, mesh, batch_axes=("data",)):
    """Deprecated alias for :func:`repro.data.loader.put_batch`.

    The historical implementation issued one ``device_put`` per leaf;
    the loader's put commits the whole batch tree in a single call (the
    runtime batches the transfers).  Kept as a thin alias for existing
    callers — new code should import ``put_batch`` (or better, feed the
    session through an :class:`repro.data.loader.InputSource`).
    """
    from .loader import put_batch
    return put_batch(batch, mesh, batch_axes)
