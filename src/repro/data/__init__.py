from .loader import (CostedSource, InputSource, Prefetcher, StreamSource,
                     SyntheticSource, make_source, put_batch)
from .pipeline import (LMTokenStream, LinRegStream, LogRegStream,
                       make_stream, shard_batch)

__all__ = ["LMTokenStream", "LinRegStream", "LogRegStream", "make_stream",
           "shard_batch", "put_batch", "InputSource", "StreamSource",
           "SyntheticSource", "CostedSource", "Prefetcher", "make_source"]
