from .pipeline import (LMTokenStream, LinRegStream, LogRegStream,
                       make_stream, shard_batch)

__all__ = ["LMTokenStream", "LinRegStream", "LogRegStream", "make_stream",
           "shard_batch"]
