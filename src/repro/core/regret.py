"""Closed-form bounds from the paper's analysis (Thm 2, Thm 4, Thm 7, App. H).

These are evaluated numerically by benchmarks/tests against the measured
behaviour of the engine — e.g. measured regret must sit below the Thm-2
bound, and the AMB/FMB wall-clock ratio must respect Thm 7.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """§4.1 constants: Lipschitz L, smoothness K, noise sigma, diameter D."""

    lip_l: float
    smooth_k: float
    sigma: float
    diameter: float


def theorem2_bound(consts: ProblemConstants, *, f_gap0: float, beta_tau: float,
                   h_wstar: float, eps: float, c_max: float, mu: float,
                   m: float) -> float:
    """Thm 2 sample-path regret bound (eq. 17)."""
    k, d, l, s = consts.smooth_k, consts.diameter, consts.lip_l, consts.sigma
    return (
        c_max * (f_gap0 + beta_tau * h_wstar)
        + 0.75 * k**2 * eps**2 * c_max * mu**1.5
        + (2 * k * d * eps + 0.5 * s**2 + 2 * l * eps) * c_max * np.sqrt(m)
    )


def theorem4_bound(consts: ProblemConstants, *, f_gap0: float, beta_tau: float,
                   h_wstar: float, eps: float, c_bar: float, b_hat: float,
                   m_bar: float) -> float:
    """Thm 4 expected regret bound."""
    k, d, l, s = consts.smooth_k, consts.diameter, consts.lip_l, consts.sigma
    return (
        c_bar * (f_gap0 + beta_tau * h_wstar)
        + 0.75 * k**2 * eps**2 * c_bar**2.5
        + (2 * k * d * eps + c_bar * s**2 / (2 * b_hat) + 2 * l * eps * c_bar)
        * np.sqrt(m_bar)
    )


def theorem7_ratio(mu: float, sigma: float, n: int) -> float:
    """S_F / S_A <= 1 + (sigma/mu) sqrt(n-1) (eq. 20)."""
    return 1.0 + (sigma / mu) * np.sqrt(max(n - 1, 0))


def shifted_exp_ratio(lam: float, zeta: float, n: int, b: float) -> float:
    """App. H exact ratio (eq. 83): (log-order speedup of AMB over FMB)."""
    h_n = float(np.sum(1.0 / np.arange(1, n + 1)))  # exact E[max] uses H_n
    s_f = h_n / lam + zeta
    s_a = (1.0 + n / b) * (1.0 / lam + zeta)
    return s_f / s_a


def shifted_exp_asymptotic_ratio(lam: float, zeta: float, n: int) -> float:
    """App. H eq. 84: S_F/S_A -> log(n) / (1 + lam*zeta)."""
    return np.log(n) / (1.0 + lam * zeta)
