"""Straggler / compute-time models (paper §5, App. H, App. I.2–I.4).

All three of the paper's experimental methodologies are implemented:

  * ``ShiftedExponential`` — the analytical model of §5/App. H: the time to
    compute a reference batch of ``b_ref`` gradients is
    ``T ~ zeta + Exp(lambda)``, with *linear progress* within an epoch
    (App. I.2: conditioned on T, computing k gradients takes k*T/b_ref).
  * ``InducedGroups`` — EC2 background-job stragglers (App. I.3): nodes are
    partitioned into groups whose per-batch times cluster around distinct
    means (the 10/20/30-second clusters of Fig. 6a).
  * ``PauseModel`` — the HPC experiment (App. I.4): after *every* gradient a
    node pauses for max(0, N(mu_j, sigma_j^2)) seconds, group-dependent.

The unified interface is per-gradient compute times: a model returns an
``(n, b_max)`` array of the times each node needs for its s-th gradient of the
epoch.  From these we derive, exactly and fully vectorised:

  * AMB batch sizes under a fixed compute budget T (cumulative time <= T),
  * FMB per-epoch finishing times for a fixed per-node batch b/n.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class StragglerModel:
    """Base: subclasses sample per-gradient times."""

    def per_gradient_times(self, key: Array, n: int, b_max: int) -> Array:
        raise NotImplementedError

    # Moments of the *per-reference-batch* time T_i(t), where available.
    def mean_batch_time(self) -> float:
        raise NotImplementedError

    def std_batch_time(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Deterministic(StragglerModel):
    """Homogeneous cluster — every gradient takes the same time."""

    grad_time: float = 1.0
    b_ref: int = 1

    def per_gradient_times(self, key, n, b_max):
        return jnp.full((n, b_max), self.grad_time, dtype=jnp.float32)

    def mean_batch_time(self):
        return self.grad_time * self.b_ref

    def std_batch_time(self):
        return 0.0


@dataclasses.dataclass(frozen=True)
class ShiftedExponential(StragglerModel):
    """T_i(t) = zeta + Exp(lam) per batch of b_ref gradients; linear progress.

    Paper App. I.2 uses lam = 2/3, zeta = 1, b_ref = 600.
    """

    lam: float = 2.0 / 3.0
    zeta: float = 1.0
    b_ref: int = 600

    def per_gradient_times(self, key, n, b_max):
        t_batch = self.zeta + jax.random.exponential(key, (n,)) / self.lam
        per_grad = t_batch / self.b_ref
        return jnp.broadcast_to(per_grad[:, None], (n, b_max)).astype(jnp.float32)

    def mean_batch_time(self):
        return self.zeta + 1.0 / self.lam

    def std_batch_time(self):
        return 1.0 / self.lam

    def expected_max_batch_time(self, n: int) -> float:
        """E[max_i T_i] = zeta + H_n / lam (App. H eq. 81, exact form)."""
        h_n = float(np.sum(1.0 / np.arange(1, n + 1)))
        return self.zeta + h_n / self.lam


@dataclasses.dataclass(frozen=True)
class InducedGroups(StragglerModel):
    """EC2 background-job stragglers (App. I.3).

    ``group_sizes`` nodes per group; group g's per-batch time is
    ``zeta_g + Exp(lam_g)`` — the paper's three clusters (~10s fast, ~20s
    intermediate, ~30s bad for b_ref=585) correspond to zetas=(9,18,27),
    lams ~ 1.
    """

    group_sizes: Sequence[int] = (5, 2, 3)
    zetas: Sequence[float] = (9.0, 18.0, 27.0)
    lams: Sequence[float] = (1.0, 1.0, 1.0)
    b_ref: int = 585

    def _node_groups(self) -> np.ndarray:
        return np.repeat(np.arange(len(self.group_sizes)), self.group_sizes)

    def per_gradient_times(self, key, n, b_max):
        groups = self._node_groups()
        if len(groups) != n:
            raise ValueError(f"group sizes sum to {len(groups)}, need n={n}")
        zeta = jnp.asarray(self.zetas, jnp.float32)[groups]
        lam = jnp.asarray(self.lams, jnp.float32)[groups]
        t_batch = zeta + jax.random.exponential(key, (n,)) / lam
        return jnp.broadcast_to(
            (t_batch / self.b_ref)[:, None], (n, b_max)
        ).astype(jnp.float32)

    def mean_batch_time(self):
        groups = self._node_groups()
        means = np.asarray(self.zetas)[groups] + 1.0 / np.asarray(self.lams)[groups]
        return float(means.mean())

    def std_batch_time(self):
        groups = self._node_groups()
        means = np.asarray(self.zetas)[groups] + 1.0 / np.asarray(self.lams)[groups]
        second = means**2 + 1.0 / np.asarray(self.lams)[groups] ** 2
        return float(np.sqrt(second.mean() - means.mean() ** 2))


@dataclasses.dataclass(frozen=True)
class PauseModel(StragglerModel):
    """HPC pause model (App. I.4): per-gradient time = base + max(0, N(mu_g, sg^2)).

    Paper: 5 groups, mus = (5, 10, 20, 35, 55) msec, sigma_g = g (g in 1..5).
    """

    group_sizes: Sequence[int] = (10, 10, 10, 10, 10)
    mus_ms: Sequence[float] = (5.0, 10.0, 20.0, 35.0, 55.0)
    base_ms: float = 1.5
    b_ref: int = 10

    def _node_groups(self) -> np.ndarray:
        return np.repeat(np.arange(len(self.group_sizes)), self.group_sizes)

    def per_gradient_times(self, key, n, b_max):
        groups = self._node_groups()
        if len(groups) != n:
            raise ValueError(f"group sizes sum to {len(groups)}, need n={n}")
        mu = jnp.asarray(self.mus_ms, jnp.float32)[groups][:, None]
        sg = (jnp.asarray(groups, jnp.float32) + 1.0)[:, None]
        pauses = mu + sg * jax.random.normal(key, (n, b_max), dtype=jnp.float32)
        pauses = jnp.maximum(pauses, 0.0)
        return (self.base_ms + pauses) / 1000.0  # seconds

    def mean_batch_time(self):
        groups = self._node_groups()
        mu = np.asarray(self.mus_ms)[groups].mean()
        return float((self.base_ms + mu) * self.b_ref / 1000.0)

    def std_batch_time(self):
        groups = self._node_groups()
        per_node = (self.base_ms + np.asarray(self.mus_ms)[groups]) * self.b_ref / 1000.0
        return float(per_node.std())


# ---------------------------------------------------------------------------
# Derived quantities
# ---------------------------------------------------------------------------

def amb_batch_sizes(per_grad_times: Array, budget_t: float) -> Array:
    """b_i(t): gradients finished before the fixed compute deadline T."""
    cum = jnp.cumsum(per_grad_times, axis=1)
    return jnp.sum(cum <= budget_t, axis=1).astype(jnp.int32)


def fmb_finish_times(per_grad_times: Array, b_per_node: int) -> Array:
    """Per-node time to finish exactly b/n gradients."""
    if b_per_node < 1:
        raise ValueError("b_per_node >= 1")
    cum = jnp.cumsum(per_grad_times, axis=1)
    if b_per_node > per_grad_times.shape[1]:
        raise ValueError("b_max too small for requested FMB batch")
    return cum[:, b_per_node - 1]


def amb_budget_from_fmb(model: StragglerModel, n: int, b_global: int) -> float:
    """Lemma 6: T = (1 + n/b) mu makes E[b_AMB] >= b_FMB.

    ``mu`` is the mean time to compute b/n gradients (Assumptions 1+2 say
    T_i is the time for b/n gradients; our models parameterise per-b_ref
    batches, so rescale).
    """
    b_per_node = b_global / n
    mu_ref = model.mean_batch_time()          # time for b_ref gradients
    b_ref = getattr(model, "b_ref", 1)
    mu = mu_ref * b_per_node / b_ref          # time for b/n gradients
    return (1.0 + n / b_global) * mu


def amb_budget_calibrated(model: StragglerModel, n: int, b_global: int,
                          key: Array | None = None, epochs: int = 64,
                          b_max: int | None = None) -> float:
    """Empirical T such that E[b(T)] ~= b_global (the paper's own method).

    Lemma 6's closed form ``(1 + n/b) mu`` assumes T_i identically
    distributed across nodes (Assumption 1).  For heterogeneous clusters
    (InducedGroups, PauseModel — App. I.3/I.4) the mean-rate formula
    overshoots: fast groups contribute disproportionately many gradients, so
    the Lemma-6 T yields E[b] >> b_global and a needlessly long epoch.  The
    paper calibrates empirically instead (App. I.4: T = 115 ms chosen so the
    average minibatch ~= 504 ~ b = 500); this reproduces that procedure by
    bisecting T against simulated per-gradient times.
    """
    import jax as _jax
    if key is None:
        key = _jax.random.PRNGKey(0)
    if b_max is None:
        b_max = max(4 * b_global // n, 16)
    times = jnp.stack([
        model.per_gradient_times(_jax.random.fold_in(key, e), n, b_max)
        for e in range(epochs)])                       # (epochs, n, b_max)

    def mean_b(t):
        return float(jnp.mean(jnp.sum(
            jnp.cumsum(times, axis=2) <= t, axis=2).sum(axis=1)))

    lo, hi = 0.0, float(jnp.sum(times, axis=2).max())
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if mean_b(mid) < b_global:
            lo = mid
        else:
            hi = mid
    return hi


def bertsimas_max_bound(mu: float, sigma: float, n: int) -> float:
    """E[max_i T_i] <= mu + sigma sqrt(n-1) (Arnold-Groeneveld / Bertsimas)."""
    return mu + sigma * float(np.sqrt(max(n - 1, 0)))
