"""Convex objectives from the paper's experiments (§6): linear + logistic regression.

Each objective exposes

    loss(w, batch)      -> scalar mean loss over the batch
    grad(w, batch)      -> mean gradient (same shape as w)
    value(w)            -> population objective F(w) when known (linreg)

plus the constants of §4.1 (Lipschitz L, smoothness K, gradient-noise sigma)
where they are available in closed form, so the regret bounds of Thm 2/4 can
be evaluated numerically against measured regret.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Linear regression (paper §6.1): y = x^T w* + eta, x ~ N(0, I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinearRegression:
    dim: int
    noise_var: float = 1e-3

    def init_w(self) -> Array:
        return jnp.zeros((self.dim,), dtype=jnp.float32)

    def sample(self, key: Array, shape: tuple[int, ...], w_star: Array):
        """Draw (x, y) with x ~ N(0, I_d), y = x.w* + N(0, noise_var)."""
        kx, kn = jax.random.split(key)
        x = jax.random.normal(kx, shape + (self.dim,), dtype=jnp.float32)
        noise = jnp.sqrt(self.noise_var) * jax.random.normal(
            kn, shape, dtype=jnp.float32
        )
        y = x @ w_star + noise
        return x, y

    def loss(self, w: Array, batch) -> Array:
        x, y = batch
        resid = x @ w - y
        return 0.5 * jnp.mean(resid * resid)

    def grad(self, w: Array, batch) -> Array:
        x, y = batch
        resid = x @ w - y                      # (b,)
        return x.T @ resid / resid.shape[-1]

    def masked_grad(self, w: Array, batch, mask: Array) -> Array:
        """Mean gradient over samples with mask==1 (variable minibatch)."""
        x, y = batch
        resid = (x @ w - y) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        return x.T @ resid / denom

    def masked_sums(self, w: Array, batch, mask: Array):
        """(grad sum, per-sample loss sum) over masked samples — for chunked
        accumulation of variable minibatches (engine)."""
        x, y = batch
        resid = x @ w - y
        gsum = x.T @ (resid * mask)
        lsum = 0.5 * jnp.sum(mask * resid * resid)
        return gsum, lsum

    def population_loss(self, w: Array, w_star: Array) -> Array:
        """F(w) = 0.5 E[(x.(w-w*) - eta)^2] = 0.5(||w-w*||^2 + noise_var)."""
        d = w - w_star
        return 0.5 * (d @ d + self.noise_var)


# ---------------------------------------------------------------------------
# Multiclass logistic regression (paper §6.2) on a synthetic MNIST-like mixture
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogisticRegression:
    dim: int = 784
    num_classes: int = 10
    bias: bool = True

    @property
    def param_dim(self) -> int:
        return self.num_classes * (self.dim + int(self.bias))

    def init_w(self) -> Array:
        return jnp.zeros((self.param_dim,), dtype=jnp.float32)

    def _unflatten(self, w: Array) -> Array:
        return w.reshape(self.num_classes, self.dim + int(self.bias))

    def make_class_means(self, key: Array, spread: float = 2.0) -> Array:
        return spread * jax.random.normal(
            key, (self.num_classes, self.dim), dtype=jnp.float32
        ) / jnp.sqrt(self.dim)

    def sample(self, key: Array, shape: tuple[int, ...], class_means: Array):
        """MNIST stand-in: x | y ~ N(mu_y, I); y uniform over classes."""
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, shape, 0, self.num_classes)
        x = class_means[y] + jax.random.normal(
            kx, shape + (self.dim,), dtype=jnp.float32
        )
        return x, y

    def _logits(self, w: Array, x: Array) -> Array:
        wm = self._unflatten(w)
        if self.bias:
            wx, b = wm[:, :-1], wm[:, -1]
            return x @ wx.T + b
        return x @ wm.T

    def loss(self, w: Array, batch) -> Array:
        x, y = batch
        logits = self._logits(w, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    def grad(self, w: Array, batch) -> Array:
        return jax.grad(self.loss)(w, batch)

    def masked_grad(self, w: Array, batch, mask: Array) -> Array:
        x, y = batch
        logits = self._logits(w, x)                       # (b, c)
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, self.num_classes, dtype=p.dtype)
        err = (p - onehot) * mask[..., None]              # (b, c)
        denom = jnp.maximum(mask.sum(), 1.0)
        gx = err.T @ x / denom                            # (c, d)
        if self.bias:
            gb = err.sum(0) / denom                       # (c,)
            return jnp.concatenate([gx, gb[:, None]], axis=1).reshape(-1)
        return gx.reshape(-1)

    def masked_sums(self, w: Array, batch, mask: Array):
        """(grad sum, per-sample loss sum) over masked samples."""
        x, y = batch
        logits = self._logits(w, x)                       # (b, c)
        logp = jax.nn.log_softmax(logits, axis=-1)
        lsum = -jnp.sum(
            mask * jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0])
        p = jnp.exp(logp)
        onehot = jax.nn.one_hot(y, self.num_classes, dtype=p.dtype)
        err = (p - onehot) * mask[..., None]              # (b, c)
        gx = err.T @ x                                    # (c, d)
        if self.bias:
            gb = err.sum(0)
            gsum = jnp.concatenate([gx, gb[:, None]], axis=1).reshape(-1)
        else:
            gsum = gx.reshape(-1)
        return gsum, lsum
