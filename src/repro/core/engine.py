"""AMB and FMB epoch engines (paper §3 + App. A pseudocode), fully in JAX.

The engine simulates ``n`` logical workers (the paper's EC2/HPC nodes) with a
simulated wall clock driven by a :mod:`repro.core.stragglers` model.  The whole
multi-epoch run is a single ``lax.scan`` — one jit compilation, thousands of
epochs.

Static-shape design (this is also how the TPU production path works, see
``repro/dist``): each epoch has a *microbatch capacity* ``b_max`` per node.
Data for the epoch is generated in ``chunks`` chunks of ``chunk`` samples and
each sample ``s`` contributes to node ``i``'s gradient iff ``s < b_i(t)`` —
an exact implementation of the paper's variable minibatch (eq. 3) with static
shapes.

Both AMB and FMB use the *same* dual-averaging + consensus machinery (the
paper's FMB baseline is identical protocol with fixed ``b`` and variable
epoch time), so the comparison isolates exactly the fixed-time-vs-fixed-batch
design decision.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import consensus as cns
from .dual_averaging import BetaSchedule, prox_step
from .stragglers import (StragglerModel, amb_batch_sizes, fmb_finish_times)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Shared AMB/FMB configuration."""

    n: int = 10                      # number of workers
    b_max: int = 1024                # per-node per-epoch microbatch capacity
    chunk: int = 128                 # data-generation chunk (memory knob)
    # --- AMB (fixed time) ---
    compute_time: float = 1.0        # T
    comm_time: float = 0.25          # T_c
    # --- FMB (fixed batch) ---
    fmb_batch_per_node: int = 64     # b/n
    # --- consensus ---
    graph: str = "paper"
    consensus_rounds: int = 5        # r
    consensus_mode: str = "gossip"   # "gossip" | "exact" (master-worker, eps=0)
    lazy: float = 0.5
    # --- dual averaging ---
    beta: BetaSchedule = BetaSchedule()
    radius: Optional[float] = None

    def __post_init__(self):
        if self.b_max % self.chunk:
            raise ValueError("b_max must be divisible by chunk")

    def build_p(self) -> np.ndarray:
        adj = cns.build_graph(self.graph, self.n)
        lazy = cns.PAPER_GRAPH_LAZY if self.graph == "paper" else self.lazy
        return cns.metropolis_weights(adj, lazy=lazy)


@dataclasses.dataclass
class History:
    """Per-epoch traces (leaves are (epochs,) or (epochs, n) arrays)."""

    wall_time: Array          # cumulative seconds at end of epoch
    batch_sizes: Array        # (epochs, n) b_i(t)
    global_batch: Array       # (epochs,) b(t)
    eval_loss: Array          # eval_fn at node-averaged iterate
    train_loss: Array         # mean per-sample loss on processed samples
    consensus_eps: Array      # max_i ||z_i - z_exact|| (Lemma 1's epsilon)
    regret: Array             # cumulative sample-path regret (eq. 16 estimate)
    potential_samples: Array  # (epochs,) c(t) = b(t) + "undone" a(t)


def _epoch_consensus(cfg: EngineConfig, p: Array, z: Array, g: Array,
                     b: Array) -> tuple[Array, Array]:
    """Consensus phase: returns (z_new (n,d), eps).

    Messages are m_i = n*b_i*(z_i+g_i) with the scalar n*b_i appended so the
    normaliser b(t) is itself agreed by consensus (paper eq. 6 normalisation).
    """
    n = cfg.n
    bw = b.astype(z.dtype)
    msg = n * bw[:, None] * (z + g)                       # (n, d)
    msg = jnp.concatenate([msg, n * bw[:, None]], axis=1)  # (n, d+1)

    if cfg.consensus_mode == "exact":
        out = cns.exact_average(msg)
    else:
        out = cns.gossip(msg, p, cfg.consensus_rounds)
    exact = cns.exact_average(msg)

    def normalise(m):
        denom = jnp.maximum(m[:, -1:], 1e-12)
        return m[:, :-1] / denom

    z_new = normalise(out)
    z_exact = normalise(exact)
    eps = jnp.max(jnp.linalg.norm(z_new - z_exact, axis=1))
    return z_new, eps


def _masked_grads(objective, w: Array, b: Array, cfg: EngineConfig,
                  key: Array, sample_args) -> tuple[Array, Array]:
    """Accumulate per-node masked gradient means + per-sample loss sums.

    Returns (g (n,d), loss_sum (n,)).  Data is generated chunk-by-chunk so the
    peak memory is (n, chunk, dim) regardless of b_max.
    """
    n, d = w.shape
    chunks = cfg.b_max // cfg.chunk

    def chunk_step(carry, c):
        gsum, lsum = carry
        ck = jax.random.fold_in(key, c)
        batch = objective.sample(ck, (n, cfg.chunk), *sample_args)
        idx = c * cfg.chunk + jnp.arange(cfg.chunk)
        mask = (idx[None, :] < b[:, None]).astype(w.dtype)   # (n, chunk)

        def node_sums(wi, xi, yi, mi):
            gs, ls = objective.masked_sums(wi, (xi, yi), mi)
            return gs, ls

        gs, ls = jax.vmap(node_sums)(w, batch[0], batch[1], mask)
        return (gsum + gs, lsum + ls), None

    (gsum, lsum), _ = jax.lax.scan(
        chunk_step, (jnp.zeros_like(w), jnp.zeros((n,), w.dtype)),
        jnp.arange(chunks))
    denom = jnp.maximum(b.astype(w.dtype), 1.0)
    return gsum / denom[:, None], lsum


def _common_epoch(cfg: EngineConfig, objective, p, w, z, t, key,
                  b, sample_args, f_star, a):
    """Gradient + consensus + update shared by AMB and FMB.

    ``a`` is the per-node count of *additional* gradients the node could have
    computed during the communication phase (paper's a_i(t)); the regret
    estimate charges those at the node's mean per-sample loss.
    """
    kdata, = jax.random.split(key, 1)
    g, lsum = _masked_grads(objective, w, b, cfg, kdata, sample_args)
    z_new, eps = _epoch_consensus(cfg, p, z, g, b)
    beta_next = cfg.beta(t + 1)
    w_new = jax.vmap(lambda zi: prox_step(zi, beta_next, cfg.radius))(z_new)

    bf = b.astype(w.dtype)
    mean_loss = lsum / jnp.maximum(bf, 1.0)
    c = bf + a.astype(w.dtype)
    regret_inc = jnp.sum(lsum + a * mean_loss - c * f_star)
    metrics = dict(
        batch_sizes=b,
        global_batch=b.sum(),
        train_loss=jnp.sum(lsum) / jnp.maximum(bf.sum(), 1.0),
        consensus_eps=eps,
        regret_inc=regret_inc,
        potential=c.sum(),
    )
    return w_new, z_new, metrics


def run(objective, model: StragglerModel, cfg: EngineConfig, *,
        mode: str, epochs: int, key: Array, sample_args=(),
        eval_fn: Optional[Callable[[Array], Array]] = None,
        f_star: float = 0.0) -> History:
    """Run AMB (`mode="amb"`) or FMB (`mode="fmb"`) for ``epochs`` epochs."""
    if mode not in ("amb", "fmb"):
        raise ValueError(mode)
    p = jnp.asarray(cfg.build_p(), jnp.float32)
    d = objective.init_w().shape[0]
    n = cfg.n
    eval_fn = eval_fn or (lambda w_bar: jnp.float32(0.0))

    w0 = jnp.zeros((n, d), jnp.float32)     # w(1) = argmin h = 0 (eq. 2)
    z0 = jnp.zeros((n, d), jnp.float32)

    def epoch(carry, t):
        w, z, clock = carry
        key_t = jax.random.fold_in(key, t)
        ktime, kgrad = jax.random.split(key_t)
        times = model.per_gradient_times(ktime, n, cfg.b_max)

        if mode == "amb":
            b = amb_batch_sizes(times, cfg.compute_time)
            # a_i(t): extra gradients that fit inside the comm window T_c.
            b_with_comm = amb_batch_sizes(
                times, cfg.compute_time + cfg.comm_time)
            a = b_with_comm - b
            epoch_time = cfg.compute_time + cfg.comm_time
        else:
            b = jnp.full((n,), cfg.fmb_batch_per_node, jnp.int32)
            finish = fmb_finish_times(times, cfg.fmb_batch_per_node)
            a = jnp.zeros((n,), jnp.int32)
            epoch_time = jnp.max(finish) + cfg.comm_time

        w_new, z_new, m = _common_epoch(
            cfg, objective, p, w, z, t, kgrad, b, sample_args, f_star, a)
        clock_new = clock + epoch_time
        out = dict(
            wall_time=clock_new,
            batch_sizes=m["batch_sizes"],
            global_batch=m["global_batch"],
            eval_loss=eval_fn(w_new.mean(0)),
            train_loss=m["train_loss"],
            consensus_eps=m["consensus_eps"],
            regret_inc=m["regret_inc"],
            potential=m["potential"],
        )
        return (w_new, z_new, clock_new), out

    (_, _, _), trace = jax.lax.scan(
        epoch, (w0, z0, jnp.float32(0.0)), jnp.arange(1, epochs + 1))

    return History(
        wall_time=trace["wall_time"],
        batch_sizes=trace["batch_sizes"],
        global_batch=trace["global_batch"],
        eval_loss=trace["eval_loss"],
        train_loss=trace["train_loss"],
        consensus_eps=trace["consensus_eps"],
        regret=jnp.cumsum(trace["regret_inc"]),
        potential_samples=trace["potential"],
    )


run_amb = partial(run, mode="amb")
run_fmb = partial(run, mode="fmb")
