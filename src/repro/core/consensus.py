"""Averaging consensus over a communication graph (paper §3, Lemma 1).

The paper's consensus phase runs ``r_i(t)`` synchronous rounds of

    m_i^(k) = sum_j P_{i,j} m_j^(k-1)

with ``P`` a positive semi-definite doubly-stochastic matrix consistent with
the (connected, undirected) graph ``G``.  This module provides:

  * graph constructors (ring, 2-D torus, complete, star/hub-and-spoke,
    Erdos-Renyi, and a 10-node "paper" graph with the same spectral gap the
    paper reports for its Fig. 2 topology),
  * Metropolis-Hastings and lazy-Metropolis doubly-stochastic weight matrices,
  * exact per-node-round gossip (vectorised over all nodes),
  * the Lemma-1 lower bound on the number of rounds for epsilon-accuracy.

Everything is pure numpy/JAX so it runs identically inside jit'd simulators.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Graph construction
# ---------------------------------------------------------------------------

def ring_graph(n: int) -> np.ndarray:
    """Adjacency of an n-cycle."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    a = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = True
    a[(idx + 1) % n, idx] = True
    return a


def torus_graph(rows: int, cols: int) -> np.ndarray:
    """Adjacency of a rows x cols 2-D torus (the TPU ICI topology)."""
    n = rows * cols
    a = np.zeros((n, n), dtype=bool)

    def nid(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = nid(r, c)
            for (dr, dc) in ((0, 1), (1, 0)):
                j = nid(r + dr, c + dc)
                if i != j:
                    a[i, j] = a[j, i] = True
    return a


def complete_graph(n: int) -> np.ndarray:
    a = np.ones((n, n), dtype=bool)
    np.fill_diagonal(a, False)
    return a


def star_graph(n: int) -> np.ndarray:
    """Hub-and-spoke: node 0 is the master (paper App. A hub-and-spoke)."""
    a = np.zeros((n, n), dtype=bool)
    a[0, 1:] = True
    a[1:, 0] = True
    return a


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> np.ndarray:
    """Connected Erdos-Renyi graph (retries until connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        u = rng.random((n, n))
        a = np.triu(u < p, k=1)
        a = a | a.T
        if is_connected(a):
            return a
    raise RuntimeError("could not sample a connected G(n,p); raise p")


PAPER_GRAPH_LAZY = 0.3


def paper_graph() -> np.ndarray:
    """A 10-node connected graph whose Metropolis P has lambda_2 = 0.888.

    The paper (App. I.1) reports lambda_2(P) = 0.888 for its Fig. 2 topology
    but does not list the edges.  We use a ring plus chords (0,4) and (2,6):
    with lazy = PAPER_GRAPH_LAZY Metropolis weights this gives
    lambda_2 = 0.8883 — the spectral gap is the only property Lemma 1 and
    the experiments depend on.
    """
    a = ring_graph(10)
    for (i, j) in ((0, 4), (2, 6)):
        a[i, j] = a[j, i] = True
    return a


def is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


GRAPHS = {
    "ring": ring_graph,
    "complete": complete_graph,
    "star": star_graph,
    "paper": lambda n=10: paper_graph(),
}


def build_graph(name: str, n: int, **kw) -> np.ndarray:
    if name == "paper":
        if n != 10:
            raise ValueError("paper graph is 10 nodes")
        return paper_graph()
    if name == "torus":
        rows = kw.get("rows")
        if rows is None:
            rows = int(np.sqrt(n))
            while n % rows:
                rows -= 1
        return torus_graph(rows, n // rows)
    if name == "erdos_renyi":
        return erdos_renyi_graph(n, kw.get("p", 0.4), kw.get("seed", 0))
    if name in GRAPHS:
        return GRAPHS[name](n)
    raise ValueError(f"unknown graph {name!r}")


# ---------------------------------------------------------------------------
# Doubly-stochastic weights
# ---------------------------------------------------------------------------

def metropolis_weights(adj: np.ndarray, lazy: float = 0.5) -> np.ndarray:
    """Lazy Metropolis-Hastings weights.

    P_{ij} = 1 / (1 + max(deg_i, deg_j)) for (i,j) in E; diagonal soaks the
    rest.  The result is symmetric doubly stochastic and, mixed with
    ``lazy`` * I, positive semi-definite (paper requires PSD P).
    """
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    deg = adj.sum(1)
    p = np.zeros((n, n), dtype=np.float64)
    ii, jj = np.nonzero(adj)
    p[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    np.fill_diagonal(p, 0.0)
    np.fill_diagonal(p, 1.0 - p.sum(1))
    if lazy > 0.0:
        p = lazy * np.eye(n) + (1.0 - lazy) * p
    return p


def lambda2(p: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude of a symmetric stochastic matrix."""
    ev = np.linalg.eigvalsh(p)
    return float(np.sort(np.abs(ev))[-2])


def spectral_gap(p: np.ndarray) -> float:
    return 1.0 - lambda2(p)


def lemma1_rounds(n: int, lip_l: float, eps: float, p: np.ndarray) -> int:
    """Paper Lemma 1: rounds needed for additive consensus accuracy eps."""
    if eps <= 0:
        raise ValueError("eps must be positive")
    gap = spectral_gap(p)
    return int(np.ceil(np.log(2.0 * np.sqrt(n) * (1.0 + 2.0 * lip_l / eps)) / gap))


# ---------------------------------------------------------------------------
# Gossip execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConsensusSpec:
    """Static description of the consensus phase."""

    p: np.ndarray                  # (n, n) doubly-stochastic
    rounds: int                    # max rounds r_max

    def __post_init__(self):
        p = np.asarray(self.p)
        if not np.allclose(p.sum(0), 1.0, atol=1e-8) or not np.allclose(
            p.sum(1), 1.0, atol=1e-8
        ):
            raise ValueError("P must be doubly stochastic")
        if (p < -1e-12).any():
            raise ValueError("P must be non-negative")


def gossip(messages: Array, p: Array, rounds: Array | int,
           max_rounds: int | None = None) -> Array:
    """Run averaging consensus.

    Args:
      messages: (n, ...) per-node message tensors m_i^(0).
      p: (n, n) doubly-stochastic matrix.
      rounds: scalar int, or (n,) per-node round counts r_i(t) (paper lets the
        number of completed rounds vary across nodes within a fixed T_c).
      max_rounds: static upper bound when ``rounds`` is per-node / traced.

    Returns:
      (n, ...) per-node consensus outputs m_i^(r_i).
    """
    messages = jnp.asarray(messages)
    p = jnp.asarray(p, dtype=messages.dtype)
    flat = messages.reshape(messages.shape[0], -1)

    if isinstance(rounds, int) and max_rounds is None:
        def body(_, m):
            return p @ m
        out = jax.lax.fori_loop(0, rounds, body, flat)
        return out.reshape(messages.shape)

    rounds = jnp.asarray(rounds)
    r_max = int(max_rounds if max_rounds is not None else rounds.max())
    per_node = jnp.broadcast_to(rounds, (messages.shape[0],))

    def body(k, m):
        nxt = p @ m
        keep = (per_node > k)[:, None]
        return jnp.where(keep, nxt, m)

    out = jax.lax.fori_loop(0, r_max, body, flat)
    return out.reshape(messages.shape)


def exact_average(messages: Array) -> Array:
    """The r -> infinity limit: every node holds the global mean."""
    mean = jnp.mean(messages, axis=0, keepdims=True)
    return jnp.broadcast_to(mean, messages.shape)


def consensus_error(messages: Array) -> Array:
    """Max_i ||m_i - mean|| — the epsilon of Lemma 1 for these messages."""
    flat = messages.reshape(messages.shape[0], -1)
    mean = flat.mean(0, keepdims=True)
    return jnp.max(jnp.linalg.norm(flat - mean, axis=-1))
