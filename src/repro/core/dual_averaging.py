"""Dual averaging (Nesterov 2009; Xiao 2010) — the paper's optimization core.

Primal update (paper eq. 7):

    w(t+1) = argmin_{w in W} { <w, z(t+1)> + beta(t+1) h(w) }

with ``h`` 1-strongly convex and ``beta(t)`` positive non-decreasing.  We use
the paper's Euclidean choice ``h(w) = ||w||^2`` (so h is 2-strongly convex; the
constant only rescales beta) over either W = R^d or an L2 ball of radius R,
for which the argmin is closed-form:

    w = -z / (2 beta)                (unconstrained)
    w = Pi_{||w||<=R} (-z / (2 beta))  (ball)

``beta(t) = K + sqrt(t / mu)`` per Lemma 8 (K = gradient-Lipschitz constant,
mu = expected per-epoch global batch).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object


@dataclasses.dataclass(frozen=True)
class BetaSchedule:
    """beta(t) = k + sqrt(t / mu) * scale; non-decreasing in t (t >= 1)."""

    k: float = 1.0
    mu: float = 1.0
    scale: float = 1.0

    def __call__(self, t: Array | int) -> Array:
        t = jnp.asarray(t, dtype=jnp.float32)
        return self.k + self.scale * jnp.sqrt(t / self.mu)


def prox_step(z: Array, beta: Array, radius: Optional[float] = None) -> Array:
    """argmin_w <w,z> + beta ||w||^2 (optionally over the ball ||w|| <= radius)."""
    w = -z / (2.0 * beta)
    if radius is not None:
        nrm = jnp.linalg.norm(w.reshape(-1))
        w = w * jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return w


def prox_step_tree(z: PyTree, beta: Array, radius: Optional[float] = None) -> PyTree:
    """Pytree version; the ball constraint is applied per-leaf."""
    return jax.tree.map(lambda zl: prox_step(zl, beta, radius), z)


@dataclasses.dataclass(frozen=True)
class DualAveraging:
    """Single-machine dual averaging (used per-node and as the FMB/AMB update)."""

    beta: BetaSchedule = BetaSchedule()
    radius: Optional[float] = None

    def init_primal(self, like: Array) -> Array:
        # w(1) = argmin h(w) = 0 (paper eq. 2).
        return jnp.zeros_like(like)

    def init_dual(self, like: Array) -> Array:
        return jnp.zeros_like(like)

    def update(self, z: Array, g: Array, t: Array | int) -> tuple[Array, Array]:
        """z(t+1) = z(t) + g(t); w(t+1) = prox(z(t+1), beta(t+1))."""
        z_new = z + g
        w_new = prox_step(z_new, self.beta(jnp.asarray(t) + 1), self.radius)
        return z_new, w_new
