"""Beyond-paper AMB extensions (recorded separately in EXPERIMENTS.md §Perf).

The paper fixes the protocol: compute for T, gossip for T_c, dual-averaging
update.  Three orthogonal improvements that keep the paper's analysis shape
(weighted consensus on dual variables) but move the wall-clock/regret
frontier:

1. **Pipelined AMB** (``run_amb_pipelined``) — the paper *counts* the
   gradients a node could compute during the consensus window as undone work
   ``a_i(t)`` (it charges them to regret, then throws them away).  We instead
   *harvest* them: during T_c each node keeps computing gradients at its
   current iterate ``w_i(t)`` and contributes them to the *next* epoch's
   weighted consensus as one-step-stale gradients.  Per-epoch sample count
   becomes ``b_i(t) + a_i(t-1)`` at zero extra wall time.  This is the
   classic delayed-gradient trick (Dekel et al. 2012 §4; staleness 1), and
   dual averaging is robust to it: the extra regret term is
   O(K * sum_t ||w(t) - w(t-1)||) = O(sqrt(m)) — same order as the bound.
   ``run_amb_delayed`` generalizes the overlap to *bounded staleness D*
   (AMB-DG): a FIFO of D in-flight consensus payloads, gradients at the
   last settled iterate, per-epoch wall time max(T, T_c/D) — the
   single-device reference for
   :func:`repro.dist.async_epochs.make_async_gossip_train_step`.

2. **Quantized gossip** (``run_amb_quantized``) — consensus rounds under a
   fixed T_c are limited by message *bytes* on a slow fabric.  Stochastic
   uniform quantization to ``bits`` bits lets (32/bits)x more rounds in the
   same window; the quantization noise is unbiased and its variance decays
   with the shrinking dynamic range as consensus converges.  Net effect:
   lower consensus error eps at equal communication time, i.e. a smaller
   Lemma-1 epsilon term in Theorem 2's regret bound.

3. **Adaptive compute budget** (``run_amb_adaptive``) — the paper fixes T
   from an *offline* estimate of mu (Lemma 6).  On a real cluster mu
   drifts (the paper itself observes EC2 transients, §6.2).  A per-epoch
   controller tracks the observed per-node gradient times with an EMA and
   re-solves Lemma 6's equation for T each epoch, keeping E[b(t)] pinned to
   the target global batch without re-profiling.  The controller itself now
   lives in :class:`repro.control.policies.BudgetPolicy` (``AdaptiveBudget``
   is a deprecated alias), where it is one of the three policies behind the
   online :class:`repro.control.Controller`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..control.policies import BudgetPolicy
from . import consensus as cns
from .dual_averaging import prox_step
from .engine import EngineConfig, History, _masked_grads
from .stragglers import StragglerModel, amb_batch_sizes

Array = jax.Array


# ---------------------------------------------------------------------------
# 1. Pipelined AMB: harvest the consensus-window gradients
# ---------------------------------------------------------------------------

def run_amb_pipelined(objective, model: StragglerModel, cfg: EngineConfig, *,
                      epochs: int, key: Array, sample_args=(),
                      eval_fn: Optional[Callable[[Array], Array]] = None,
                      f_star: float = 0.0) -> History:
    """AMB with compute/communication overlap (staleness-1 gradients).

    Epoch t consensus message of node i:

        m_i = n * (b_i(t) + a_i(t-1)) * [z_i(t) + g_i(t)]

    where g_i(t) is the weighted mean of b_i(t) fresh gradients at w_i(t)
    and a_i(t-1) stale gradients evaluated at w_i(t-1) during the previous
    consensus window.  Wall time per epoch is identical to AMB (T + T_c);
    only idle cycles are reclaimed.
    """
    p = jnp.asarray(cfg.build_p(), jnp.float32)
    d = objective.init_w().shape[0]
    n = cfg.n
    eval_fn = eval_fn or (lambda w_bar: jnp.float32(0.0))

    w0 = jnp.zeros((n, d), jnp.float32)
    z0 = jnp.zeros((n, d), jnp.float32)
    stale_g0 = jnp.zeros((n, d), jnp.float32)   # sum of stale grads
    stale_b0 = jnp.zeros((n,), jnp.int32)

    def epoch(carry, t):
        w, z, clock, stale_gsum, stale_b = carry
        # Same (ktime, kgrad) derivation as run_amb: epoch 1 (no stale
        # gradients yet) must draw identical straggler times and hence an
        # identical global batch; kstale is derived separately.
        key_t = jax.random.fold_in(key, t)
        ktime, kgrad = jax.random.split(key_t)
        kstale = jax.random.fold_in(kgrad, 1)
        times = model.per_gradient_times(ktime, n, cfg.b_max)

        b = amb_batch_sizes(times, cfg.compute_time)
        b_with_comm = amb_batch_sizes(times, cfg.compute_time + cfg.comm_time)
        a = b_with_comm - b

        # fresh gradients at w (mean over b_i samples) -> sums
        g_fresh, lsum = _masked_grads(objective, w, b, cfg, kgrad, sample_args)
        bf = b.astype(w.dtype)
        fresh_gsum = g_fresh * bf[:, None]

        # combine with the stale sums harvested during the previous T_c
        tot_b = bf + stale_b.astype(w.dtype)
        g_comb = (fresh_gsum + stale_gsum) / jnp.maximum(tot_b, 1.0)[:, None]

        # weighted consensus over z + g_comb, weights = total contributions
        msg = n * tot_b[:, None] * (z + g_comb)
        msg = jnp.concatenate([msg, n * tot_b[:, None]], axis=1)
        if cfg.consensus_mode == "exact":
            out = cns.exact_average(msg)
        else:
            out = cns.gossip(msg, p, cfg.consensus_rounds)
        exact = cns.exact_average(msg)
        normalise = lambda m: m[:, :-1] / jnp.maximum(m[:, -1:], 1e-12)
        z_new = normalise(out)
        eps = jnp.max(jnp.linalg.norm(z_new - normalise(exact), axis=1))

        beta_next = cfg.beta(t + 1)
        w_new = jax.vmap(
            lambda zi: prox_step(zi, beta_next, cfg.radius))(z_new)

        # harvest NEXT epoch's stale gradients: a_i samples at *current* w
        # (the iterate nodes hold during this epoch's consensus window).
        g_stale, _ = _masked_grads(objective, w, a, cfg, kstale, sample_args)
        af = a.astype(w.dtype)
        new_stale_gsum = g_stale * af[:, None]

        mean_loss = lsum / jnp.maximum(bf, 1.0)
        c = tot_b                      # all contributions are *used* work
        regret_inc = jnp.sum(lsum + af * mean_loss - c * f_star)
        clock_new = clock + cfg.compute_time + cfg.comm_time
        out_t = dict(
            wall_time=clock_new, batch_sizes=b + stale_b,
            global_batch=(b + stale_b).sum(),
            eval_loss=eval_fn(w_new.mean(0)),
            train_loss=jnp.sum(lsum) / jnp.maximum(bf.sum(), 1.0),
            consensus_eps=eps, regret_inc=regret_inc, potential=c.sum(),
        )
        return (w_new, z_new, clock_new, new_stale_gsum, a), out_t

    (_, _, _, _, _), tr = jax.lax.scan(
        epoch, (w0, z0, jnp.float32(0.0), stale_g0, stale_b0),
        jnp.arange(1, epochs + 1))
    return History(
        wall_time=tr["wall_time"], batch_sizes=tr["batch_sizes"],
        global_batch=tr["global_batch"], eval_loss=tr["eval_loss"],
        train_loss=tr["train_loss"], consensus_eps=tr["consensus_eps"],
        regret=jnp.cumsum(tr["regret_inc"]),
        potential_samples=tr["potential"])


# ---------------------------------------------------------------------------
# 1b. Delayed-gradient AMB (AMB-DG): bounded staleness D
# ---------------------------------------------------------------------------

def run_amb_delayed(objective, model: StragglerModel, cfg: EngineConfig, *,
                    staleness: int, epochs: int, key: Array,
                    sample_args=(),
                    eval_fn: Optional[Callable[[Array], Array]] = None,
                    f_star: float = 0.0) -> History:
    """AMB with bounded-staleness delayed gradients (AMB-DG reference).

    The single-device analogue of
    :func:`repro.dist.async_epochs.make_async_gossip_train_step`: a FIFO
    of ``staleness`` in-flight consensus payloads.  Epoch t settles the
    payload enqueued at epoch ``t - D`` (its consensus has had D compute
    windows to complete), computes gradients at the last *settled*
    iterate — delayed by D epochs — and enqueues ``n b_i (z_i + g_i)``
    on the settled dual.  The settle is an *increment* against a
    snapshot of the dual the payload was packed on, with the dual term
    mixing-damped by ``gamma = 1/(2D)`` on the wire (see
    :mod:`repro.dist.async_epochs`): ``payload = n b (gamma z + g)``
    and ``z <- z + (agreed - gamma snapshot)`` — the full-strength
    weighted-mean gradient plus a gamma-damped consensus pull.  The
    damping is what keeps deep staleness stable: a D-delayed
    contraction at full strength has unit-circle-crossing roots for
    D >= 2, while replacing the dual outright would split it into D
    interleaved chains (divergent too); at D = 1 gamma = 1 recovers
    the sequential update.  Dual averaging tolerates the staleness (the
    extra
    regret term is O(D * sum_t ||w(t) - w(t-1)||), same order as the
    bound for constant D), and the wall-clock per epoch drops from
    ``T + T_c`` to ``max(T, T_c / D)`` — consensus no longer needs to
    fit in one window, only to sustain one settle per window.

    ``staleness=0`` is the sequential protocol (settle-before-update,
    no delay) and is rejected here to keep the queue shape static; use
    :func:`repro.core.engine.run_amb` for that.
    """
    if staleness < 1:
        raise ValueError(f"staleness must be >= 1, got {staleness}")
    p = jnp.asarray(cfg.build_p(), jnp.float32)
    d = objective.init_w().shape[0]
    n = cfg.n
    D = staleness
    eval_fn = eval_fn or (lambda w_bar: jnp.float32(0.0))

    gamma = 1.0 if D == 1 else 1.0 / (2.0 * D)   # delayed-mixing damping

    w0 = jnp.zeros((n, d), jnp.float32)
    z0 = jnp.zeros((n, d), jnp.float32)
    queue0 = jnp.zeros((D, n, d + 1), jnp.float32)   # payload | weight col
    snaps0 = jnp.zeros((D, n, d), jnp.float32)       # dual at enqueue time

    def settle(z, payload, snapshot):
        """One queued payload's consensus folded into the dual as the
        increment ``agreed - gamma * snapshot``; zero payloads no-op."""
        if cfg.consensus_mode == "exact":
            out = cns.exact_average(payload)
        else:
            out = cns.gossip(payload, p, cfg.consensus_rounds)
        live = out[:, -1:] > 1e-6
        agreed = out[:, :-1] / jnp.maximum(out[:, -1:], 1e-12)
        z_new = z + jnp.where(live, agreed - gamma * snapshot, 0.0)
        exact = cns.exact_average(payload)
        agreed_ex = exact[:, :-1] / jnp.maximum(exact[:, -1:], 1e-12)
        z_ex = z + jnp.where(exact[:, -1:] > 1e-6,
                             agreed_ex - gamma * snapshot, 0.0)
        eps = jnp.max(jnp.linalg.norm(z_new - z_ex, axis=1))
        return z_new, eps

    def epoch(carry, t):
        w, z, queue, snaps, clock = carry
        key_t = jax.random.fold_in(key, t)
        ktime, kgrad = jax.random.split(key_t)
        times = model.per_gradient_times(ktime, n, cfg.b_max)
        b = amb_batch_sizes(times, cfg.compute_time)

        # gradients at the last *settled* iterate (staleness D), then
        # settle the due payload (enqueued at epoch t - D)
        g, lsum = _masked_grads(objective, w, b, cfg, kgrad, sample_args)
        z_new, eps = settle(z, queue[0], snaps[0])

        bw = b.astype(w.dtype)
        payload = jnp.concatenate(
            [n * bw[:, None] * (gamma * z_new + g), n * bw[:, None]],
            axis=1)
        queue_new = jnp.concatenate([queue[1:], payload[None]], axis=0)
        snaps_new = jnp.concatenate([snaps[1:], z_new[None]], axis=0)

        beta_next = cfg.beta(t + 1)
        w_new = jax.vmap(
            lambda zi: prox_step(zi, beta_next, cfg.radius))(z_new)

        # per-epoch wall time: consensus gets D windows, so only T_c/D
        # must fit alongside the compute window
        clock_new = clock + jnp.maximum(cfg.compute_time,
                                        cfg.comm_time / D)
        regret_inc = jnp.sum(lsum - bw * f_star)
        out_t = dict(
            wall_time=clock_new, batch_sizes=b, global_batch=b.sum(),
            eval_loss=eval_fn(w_new.mean(0)),
            train_loss=jnp.sum(lsum) / jnp.maximum(bw.sum(), 1.0),
            consensus_eps=eps, regret_inc=regret_inc, potential=b.sum(),
        )
        return (w_new, z_new, queue_new, snaps_new, clock_new), out_t

    (_, _, _, _, _), tr = jax.lax.scan(
        epoch, (w0, z0, queue0, snaps0, jnp.float32(0.0)),
        jnp.arange(1, epochs + 1))
    return History(
        wall_time=tr["wall_time"], batch_sizes=tr["batch_sizes"],
        global_batch=tr["global_batch"], eval_loss=tr["eval_loss"],
        train_loss=tr["train_loss"], consensus_eps=tr["consensus_eps"],
        regret=jnp.cumsum(tr["regret_inc"]),
        potential_samples=tr["potential"])


# ---------------------------------------------------------------------------
# 2. Quantized gossip: more rounds per byte-budget
# ---------------------------------------------------------------------------

def quantize_unbiased(x: Array, bits: int, key: Array) -> Array:
    """Stochastic uniform quantization, unbiased: E[q(x)] = x.

    Per-row (per-node message) dynamic range; levels = 2^bits - 1.
    """
    levels = float(2 ** bits - 1)
    lo = x.min(axis=-1, keepdims=True)
    hi = x.max(axis=-1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / levels
    u = (x - lo) / scale
    fl = jnp.floor(u)
    prob = u - fl
    # partitionable threefry: each shard of a worker-sharded x draws its
    # own bits locally; the default (sequential) impl reshards ~4 bytes of
    # u32 per element across the mesh — more interconnect traffic than the
    # quantized wire planes it randomizes (see BENCH_dist multipod).
    with jax.threefry_partitionable(True):
        draws = jax.random.uniform(key, x.shape)
    rnd = (draws < prob).astype(x.dtype)
    # Clamp: f32 rounding can put the row max a hair above `levels`, and
    # the stochastic up-round would then emit level 2^bits — which wraps
    # to 0 in a uint8 wire format (and overshoots hi here).
    return lo + jnp.minimum(fl + rnd, levels) * scale


def gossip_quantized(messages: Array, p: Array, rounds: int, bits: int,
                     key: Array) -> Array:
    """Gossip with *difference* (delta) compression.

    Naive per-round quantization injects noise proportional to the full
    message magnitude every round — it never converges below the
    quantization floor (we measured eps ~10x WORSE than fp32 at r=5; see
    EXPERIMENTS.md §Perf, refuted-hypothesis log).  The fix, standard in
    compressed decentralized optimization (cf. CHOCO-SGD, Koloskova et al.
    2019), is to transmit quantized *deltas* against a publicly-known
    replica h_j of each node's value:

        send_j   = q(m_j - h_j)          (shrinks as gossip converges)
        h_j     += send_j                (all nodes update the same replica)
        m_i     <- P_ii m_i + sum_{j != i} P_ij h_j

    The self term stays exact.  Delta magnitude decays ~ lambda_2^k, so the
    injected noise decays with it, and the int8 wire format still buys
    (32/bits)x the rounds per byte budget.
    """
    messages = jnp.asarray(messages)
    p = jnp.asarray(p, messages.dtype)
    flat = messages.reshape(messages.shape[0], -1)
    diag = jnp.diag(p)[:, None]
    off = p - jnp.diag(jnp.diag(p))

    def body(k, carry):
        m, h = carry
        delta_q = quantize_unbiased(m - h, bits, jax.random.fold_in(key, k))
        h = h + delta_q
        m = diag * m + off @ h
        return m, h

    # replicas start at zero: round 1's delta is the (quantized) full
    # message, so every round is an int8 wire message — strict byte parity
    # with the (32/bits)x round multiplier.
    out, _ = jax.lax.fori_loop(0, rounds, body, (flat, jnp.zeros_like(flat)))
    return out.reshape(messages.shape)


def run_amb_quantized(objective, model: StragglerModel, cfg: EngineConfig, *,
                      bits: int = 8, epochs: int, key: Array,
                      sample_args=(), eval_fn=None,
                      f_star: float = 0.0) -> History:
    """AMB where the fixed T_c buys (32/bits) x the rounds via quantization."""
    rounds = int(cfg.consensus_rounds * 32 / bits)
    p = jnp.asarray(cfg.build_p(), jnp.float32)
    d = objective.init_w().shape[0]
    n = cfg.n
    eval_fn = eval_fn or (lambda w_bar: jnp.float32(0.0))

    w0 = jnp.zeros((n, d), jnp.float32)
    z0 = jnp.zeros((n, d), jnp.float32)

    def epoch(carry, t):
        w, z, clock = carry
        key_t = jax.random.fold_in(key, t)
        ktime, kgrad, kq = jax.random.split(key_t, 3)
        times = model.per_gradient_times(ktime, n, cfg.b_max)
        b = amb_batch_sizes(times, cfg.compute_time)

        g, lsum = _masked_grads(objective, w, b, cfg, kgrad, sample_args)
        bw = b.astype(w.dtype)
        payload = n * bw[:, None] * (z + g)           # (n, d) — quantized
        weight = n * bw[:, None]                      # (n, 1) — sent exact:
        # one fp32 scalar per node per round is byte-noise next to d coords,
        # and folding it into the quantized row would blow up the dynamic
        # range (n*b_i ~ 1e3-1e4 vs O(1) dual coordinates).
        out_p = gossip_quantized(payload, p, rounds, bits, kq)
        out_w = cns.gossip(weight, p, rounds)
        z_new = out_p / jnp.maximum(out_w, 1e-12)
        exact = cns.exact_average(
            jnp.concatenate([payload, weight], axis=1))
        z_exact = exact[:, :-1] / jnp.maximum(exact[:, -1:], 1e-12)
        eps = jnp.max(jnp.linalg.norm(z_new - z_exact, axis=1))
        beta_next = cfg.beta(t + 1)
        w_new = jax.vmap(
            lambda zi: prox_step(zi, beta_next, cfg.radius))(z_new)

        clock_new = clock + cfg.compute_time + cfg.comm_time
        mean_loss = lsum / jnp.maximum(bw, 1.0)
        regret_inc = jnp.sum(lsum - bw * f_star)
        out_t = dict(
            wall_time=clock_new, batch_sizes=b, global_batch=b.sum(),
            eval_loss=eval_fn(w_new.mean(0)),
            train_loss=jnp.sum(lsum) / jnp.maximum(bw.sum(), 1.0),
            consensus_eps=eps, regret_inc=regret_inc, potential=b.sum(),
        )
        return (w_new, z_new, clock_new), out_t

    (_, _, _), tr = jax.lax.scan(
        epoch, (w0, z0, jnp.float32(0.0)), jnp.arange(1, epochs + 1))
    return History(
        wall_time=tr["wall_time"], batch_sizes=tr["batch_sizes"],
        global_batch=tr["global_batch"], eval_loss=tr["eval_loss"],
        train_loss=tr["train_loss"], consensus_eps=tr["consensus_eps"],
        regret=jnp.cumsum(tr["regret_inc"]),
        potential_samples=tr["potential"])


# ---------------------------------------------------------------------------
# 3. Adaptive compute budget: online Lemma-6
# ---------------------------------------------------------------------------

# Deprecated alias: the online Lemma-6 controller moved to
# ``repro.control.policies.BudgetPolicy`` (same fields, same ``init`` /
# ``update`` API and numerics — the stationary fixed point still matches
# Lemma 6, see tests/test_control.py), where it is one of the three
# policies behind ``repro.control.Controller``.  Import it from
# ``repro.control`` in new code; this name stays for existing callers.
AdaptiveBudget = BudgetPolicy


def run_amb_adaptive(objective, model_fn, cfg: EngineConfig, *,
                     controller: AdaptiveBudget, epochs: int, key: Array,
                     sample_args=(), eval_fn=None,
                     f_star: float = 0.0) -> History:
    """AMB with the adaptive-T controller.

    ``model_fn(t)`` returns the straggler model for epoch t — allowing
    non-stationary clusters (the case fixed-T cannot handle).
    """
    p = jnp.asarray(cfg.build_p(), jnp.float32)
    d = objective.init_w().shape[0]
    n = cfg.n
    eval_fn = eval_fn or (lambda w_bar: jnp.float32(0.0))

    w = jnp.zeros((n, d), jnp.float32)
    z = jnp.zeros((n, d), jnp.float32)
    ctrl = controller.init(cfg.compute_time)
    clock = 0.0
    rows = []
    regret = 0.0

    # non-stationary model -> per-epoch python loop (epochs is small here)
    step = _make_adaptive_step(objective, cfg, p, sample_args, f_star,
                               controller)
    for t in range(1, epochs + 1):
        key_t = jax.random.fold_in(key, t)
        model = model_fn(t)
        ktime, kgrad = jax.random.split(key_t)
        times = model.per_gradient_times(ktime, n, cfg.b_max)
        w, z, ctrl, m = step(w, z, ctrl, times, kgrad, jnp.int32(t))
        clock += float(ctrl["last_epoch_time"])
        regret += float(m["regret_inc"])
        rows.append(dict(wall_time=clock, batch_sizes=np.asarray(m["b"]),
                         global_batch=float(m["b"].sum()),
                         eval_loss=float(eval_fn(w.mean(0))),
                         train_loss=float(m["train_loss"]),
                         consensus_eps=float(m["eps"]), regret=regret,
                         potential=float(m["b"].sum())))

    return History(
        wall_time=jnp.asarray([r["wall_time"] for r in rows]),
        batch_sizes=jnp.asarray(np.stack([r["batch_sizes"] for r in rows])),
        global_batch=jnp.asarray([r["global_batch"] for r in rows]),
        eval_loss=jnp.asarray([r["eval_loss"] for r in rows]),
        train_loss=jnp.asarray([r["train_loss"] for r in rows]),
        consensus_eps=jnp.asarray([r["consensus_eps"] for r in rows]),
        regret=jnp.asarray([r["regret"] for r in rows]),
        potential_samples=jnp.asarray([r["potential"] for r in rows]))


def _make_adaptive_step(objective, cfg, p, sample_args, f_star, controller):
    @jax.jit
    def step(w, z, ctrl, times, kgrad, t):
        t_budget = ctrl["t_budget"]
        b = amb_batch_sizes(times, t_budget)
        g, lsum = _masked_grads(objective, w, b, cfg, kgrad, sample_args)
        n = cfg.n
        bw = b.astype(w.dtype)
        msg = n * bw[:, None] * (z + g)
        msg = jnp.concatenate([msg, n * bw[:, None]], axis=1)
        if cfg.consensus_mode == "exact":
            out = cns.exact_average(msg)
        else:
            out = cns.gossip(msg, p, cfg.consensus_rounds)
        exact = cns.exact_average(msg)
        normalise = lambda m: m[:, :-1] / jnp.maximum(m[:, -1:], 1e-12)
        z_new = normalise(out)
        eps = jnp.max(jnp.linalg.norm(z_new - normalise(exact), axis=1))
        beta_next = cfg.beta(t.astype(jnp.float32) + 1.0)
        w_new = jax.vmap(
            lambda zi: prox_step(zi, beta_next, cfg.radius))(z_new)

        new_ctrl = controller.update(
            {"t_budget": t_budget, "tau": ctrl["tau"]}, b)
        new_ctrl["last_epoch_time"] = t_budget + cfg.comm_time
        regret_inc = jnp.sum(lsum - bw * f_star)
        metrics = dict(b=b, eps=eps, regret_inc=regret_inc,
                       train_loss=jnp.sum(lsum) / jnp.maximum(bw.sum(), 1.0))
        return w_new, z_new, new_ctrl, metrics
    return step
