"""Anytime Minibatch (AMB) core — the paper's contribution as composable JAX.

Public API:

  * :mod:`repro.core.consensus` — graphs, doubly-stochastic P, gossip.
  * :mod:`repro.core.dual_averaging` — dual averaging prox + beta schedules.
  * :mod:`repro.core.stragglers` — compute-time models (shifted exponential,
    induced EC2 stragglers, HPC pause model).
  * :mod:`repro.core.engine` — AMB + FMB multi-node epoch engines.
  * :mod:`repro.core.objectives` — the paper's convex workloads.
  * :mod:`repro.core.regret` — closed-form bounds (Thm 2/4/7, App. H).
  * :mod:`repro.core.extensions` — beyond-paper: pipelined AMB, quantized
    gossip, adaptive compute budget.
"""
from . import (consensus, dual_averaging, engine, extensions, objectives,
               regret, stragglers)
from .dual_averaging import BetaSchedule, DualAveraging, prox_step, prox_step_tree
from .engine import EngineConfig, History, run, run_amb, run_fmb
from .stragglers import (Deterministic, InducedGroups, PauseModel,
                         ShiftedExponential, amb_batch_sizes,
                         amb_budget_calibrated, amb_budget_from_fmb,
                         fmb_finish_times)

__all__ = [
    "consensus", "dual_averaging", "engine", "objectives", "regret",
    "stragglers", "BetaSchedule", "DualAveraging", "prox_step",
    "prox_step_tree", "EngineConfig", "History", "run", "run_amb", "run_fmb",
    "Deterministic", "InducedGroups", "PauseModel", "ShiftedExponential",
    "amb_batch_sizes", "amb_budget_calibrated", "amb_budget_from_fmb",
    "fmb_finish_times",
]
