"""Minimal pytree checkpointing: npz arrays + JSON manifest (no orbax here).

Layout:  <dir>/step_<n>/arrays.npz + manifest.json.  Leaves are addressed by
their joined pytree path; bfloat16 round-trips via a uint16 view (npz has no
native bf16).  Atomic via write-to-temp + rename.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves = {}
    manifest = {"step": step, "leaves": {}}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            manifest["leaves"][key] = _BF16
            arr = arr.view(np.uint16)
        else:
            manifest["leaves"][key] = str(arr.dtype)
        leaves[key] = arr

    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_"))
    try:
        np.savez(tmp / "arrays.npz", **leaves)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = directory / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        return final
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")]
    return max(steps) if steps else None


def load_checkpoint(directory: str | os.PathLike, step: int,
                    like: Any) -> Any:
    """Restore into the structure of ``like`` (an example pytree)."""
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    def restore(path, leaf):
        key = _path_str(path)
        arr = data[key]
        if manifest["leaves"][key] == _BF16:
            arr = arr.view(jnp.bfloat16)
        out = jnp.asarray(arr)
        if out.shape != leaf.shape:
            raise ValueError(f"{key}: shape {out.shape} != {leaf.shape}")
        return out

    return jax.tree_util.tree_map_with_path(restore, like)
