"""Token sampling for the serving tier.

Greedy (argmax) by default; temperature + top-k when requested.  The
branch between greedy and stochastic is a *trace-time* python decision on
the frozen :class:`SamplingSpec`, so the slot engine jits exactly one
sampler for its lifetime — no recompilation per request.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG = jnp.float32(-1e30)


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """Frozen sampling configuration.

    ``temperature <= 0`` means greedy decode (``top_k`` ignored).
    ``top_k == 0`` means sample from the full distribution.  ``seed``
    seeds the engine's PRNG chain; the same (spec, request sequence)
    replays the same tokens exactly.
    """
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sample_token(logits: Array, key: Optional[Array] = None, *,
                 temperature: float = 0.0, top_k: int = 0) -> Array:
    """Sample next-token ids from ``logits`` (..., V) -> (...,) int32.

    ``temperature <= 0`` is greedy argmax and ignores ``key``; otherwise
    ``key`` is required and ``top_k > 0`` restricts sampling to the k
    highest-probability tokens (mask below the per-row k-th logit).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("temperature > 0 requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.float32(temperature)
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, _NEG, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
