"""``repro.serve`` — continuous-batching serving tier with background
AMB fine-tuning under the anytime budget.

Four planes, one fixed-time contract:

  * :mod:`repro.serve.request` — ``Request`` lifecycle, the arrival
    queue, ``AdmissionPolicy``, and ``synthetic_requests`` workloads.
  * :mod:`repro.serve.slots` — ``SlotEngine``: continuous batching over
    a fixed-shape slot array (bucketed batch-1 prefill, one jitted
    insert/decode/evict, slot reuse without recompilation) plus the
    ``static_generate`` parity reference.
  * :mod:`repro.serve.scheduler` — ``ServeScheduler`` runs decode
    rounds and background :class:`repro.api.AMBSession` fine-tune
    epochs under one fixed ``round_budget_s`` (AMB's contract: the
    budget is fixed, the work is whatever fits); ``serve_static`` is
    the timed rebatching baseline; ``WallClock`` / ``SyntheticClock``
    are the pluggable time sources.
  * :mod:`repro.serve.metrics` — ``ServeMetrics``: TTFT / TPOT /
    latency p50-p99, tokens/s, train-loss trajectory, streamed through
    :class:`repro.metrics.MetricsLogger`.

``launch/serve.py`` is a thin CLI over this package; the
``dist_serve`` section of ``benchmarks/dist_step.py`` compares the two
lanes in one run.
"""
from .metrics import ServeMetrics, request_record            # noqa: F401
from .request import AdmissionPolicy, Request, RequestQueue  # noqa: F401
from .request import synthetic_requests                      # noqa: F401
from .sampling import SamplingSpec, sample_token             # noqa: F401
from .scheduler import ServeClock, ServeReport, ServeScheduler  # noqa: F401
from .scheduler import SyntheticClock, WallClock, serve_static  # noqa: F401
from .slots import SlotEngine, bucket_len, static_generate   # noqa: F401

__all__ = [
    "AdmissionPolicy", "Request", "RequestQueue", "SamplingSpec",
    "ServeClock", "ServeMetrics", "ServeReport", "ServeScheduler",
    "SlotEngine", "SyntheticClock", "WallClock", "bucket_len",
    "request_record", "sample_token", "serve_static", "static_generate",
    "synthetic_requests",
]
