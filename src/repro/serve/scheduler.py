"""Scheduler: decode rounds + background AMB fine-tuning on one budget.

This is the paper's fixed-time contract transplanted to serving.  AMB
gives every node a wall-clock budget T and takes whatever gradients fit
(b_i(t) varies, the deadline does not).  Here every *round* gets a
fixed budget ``round_budget_s``; decode consumes it first (requests
contribute whatever tokens fit), and whatever is left over is absorbed
by AMB fine-tune epochs through an :class:`repro.api.AMBSession` — the
serving analogue of exploiting stragglers: idle slot time becomes
training progress instead of waste.  Under load the leftover shrinks
to zero and training backs off automatically; no preemption logic, the
budget arithmetic *is* the policy (AMB-DG, arXiv:2012.08616, shows the
equivalent overlap of compute with stale updates converges).

Timekeeping is pluggable: :class:`WallClock` for real serving,
:class:`SyntheticClock` (deterministic per-op costs) so tests and the
bench can assert budget accounting and SLO values exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist import use_sharding
from ..models import decode_step, prefill
from .metrics import ServeMetrics
from .request import Request, RequestQueue
from .sampling import SamplingSpec, sample_token
from .slots import SlotEngine


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

class ServeClock:
    """Time source + cost model: ``now()``, ``charge(kind, n)``,
    ``wait_until(t)``.  ``charge`` advances synthetic time by the
    configured per-op cost (a no-op on the wall clock, where ops take
    real time)."""

    def now(self) -> float:
        raise NotImplementedError

    def charge(self, kind: str, n: int = 1) -> None:
        raise NotImplementedError

    def wait_until(self, t: float) -> None:
        raise NotImplementedError


class WallClock(ServeClock):
    """Monotonic wall time from construction; ``wait_until`` sleeps."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def charge(self, kind: str, n: int = 1) -> None:
        pass

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class SyntheticClock(ServeClock):
    """Deterministic clock: ops cost exactly what the test configures.

    ``prefill`` is charged per prompt token, ``decode`` per round,
    ``train`` per fine-tune epoch.  Every scheduler timestamp becomes
    an exact arithmetic consequence of these three numbers.
    """

    def __init__(self, *, prefill_tok_s: float = 0.0,
                 decode_round_s: float = 0.0, train_epoch_s: float = 0.0):
        self.t = 0.0
        self.costs = {"prefill": prefill_tok_s, "decode": decode_round_s,
                      "train": train_epoch_s}

    def now(self) -> float:
        return self.t

    def charge(self, kind: str, n: int = 1) -> None:
        self.t += self.costs.get(kind, 0.0) * n

    def wait_until(self, t: float) -> None:
        if t > self.t:
            self.t = t


# ---------------------------------------------------------------------------
# Continuous-batching scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    summary: dict
    requests: list[Request]
    rounds: int
    train_epochs: int


class ServeScheduler:
    """Round loop: admit -> decode under budget -> absorb leftover with
    AMB fine-tune epochs -> idle-wait to the next arrival.

    Admission is continuous: a slot freed mid-round is refilled in the
    same round (the engine never waits for a batch boundary).  The
    fine-tune cost estimate is the *minimum observed* epoch time (an
    unknown cost counts as zero, so the first epoch always runs and
    teaches the estimate — the first epoch carries jit compilation, so
    min, not mean, tracks the steady state); an epoch is started only
    if the estimate fits the remaining budget, which is what makes
    training back off under serving load.

    Serving decodes against the *live* fine-tuned primal: after every
    absorbed epoch the engine's params are re-fetched from the session
    (mandatory, not cosmetic — the session's donated train step frees
    the previous iterate's buffers in place).
    """

    def __init__(self, engine: SlotEngine, queue: RequestQueue, *,
                 round_budget_s: float, clock: Optional[ServeClock] = None,
                 session=None, train_epochs: int = 0,
                 metrics: Optional[ServeMetrics] = None):
        self.engine = engine
        self.queue = queue
        self.round_budget_s = round_budget_s
        self.clock = clock if clock is not None else WallClock()
        self.session = session
        self.train_epochs = train_epochs if session is not None else 0
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._train_source = session.batch_source() \
            if session is not None and train_epochs > 0 else None
        self._train_cost: Optional[float] = None
        self.trained = 0
        self.rounds = 0
        self.finished: list[Request] = []

    # -- pieces ------------------------------------------------------------

    def _admit_ready(self) -> int:
        n = 0
        while self.engine.has_free:
            req = self.queue.pop_ready(self.clock.now())
            if req is None:
                break
            req.admit_s = self.clock.now()
            self.engine.insert(req)
            self.clock.charge("prefill", req.prompt_len)
            req.first_token_s = self.clock.now()
            if req.done:                     # max_new_tokens == 1 / EOS
                req.finish_s = self.clock.now()
                self.metrics.complete(req)
                self.finished.append(req)
            n += 1
        return n

    def _ready_now(self) -> bool:
        nxt = self.queue.next_arrival_s()
        return (nxt is not None and nxt <= self.clock.now()
                and self.engine.has_free)

    def _train_once(self, deadline: float) -> bool:
        est = self._train_cost if self._train_cost is not None else 0.0
        now = self.clock.now()
        if now >= deadline or now + est > deadline:
            return False
        m = self.session.step(
            self._train_source.batch(self.session.steps_done))
        # the session's donated train step freed the previous primal's
        # buffers — re-fetch or the engine decodes against deleted arrays
        self.engine.params = self.session.params
        self.clock.charge("train")
        dt = self.clock.now() - now
        self._train_cost = dt if self._train_cost is None \
            else min(self._train_cost, dt)
        self.metrics.train_step(self.session.steps_done - 1, m["loss"])
        self.trained += 1
        return True

    # -- the loop ----------------------------------------------------------

    def run(self, max_rounds: int = 1_000_000) -> ServeReport:
        clock = self.clock
        while len(self.queue) or self.engine.active_count:
            if self.rounds >= max_rounds:
                raise RuntimeError("serve scheduler exceeded max_rounds")
            self.rounds += 1
            end = clock.now() + self.round_budget_s
            self._admit_ready()
            while self.engine.active_count and clock.now() < end:
                finished = self.engine.decode_round()
                clock.charge("decode")
                now = clock.now()
                for f in finished:
                    f.finish_s = now
                    self.metrics.complete(f)
                    self.finished.append(f)
                if finished:
                    self._admit_ready()      # continuous refill
            # leftover budget -> background AMB fine-tuning
            while (self._train_source is not None
                   and self.trained < self.train_epochs
                   and not self._ready_now()):
                if not self._train_once(end):
                    break
            # idle: jump to the next arrival (bounded by the round end)
            if not self.engine.active_count and len(self.queue):
                nxt = self.queue.next_arrival_s()
                clock.wait_until(min(nxt, end))
        return ServeReport(self.metrics.summary(), list(self.finished),
                           self.rounds, self.trained)


# ---------------------------------------------------------------------------
# Static rebatching baseline (the thing continuous batching beats)
# ---------------------------------------------------------------------------

def serve_static(params, cfg, requests: list[Request], *, batch: int,
                 cache_len: int, sampling: Optional[SamplingSpec] = None,
                 eos_id: Optional[int] = None,
                 clock: Optional[ServeClock] = None,
                 metrics: Optional[ServeMetrics] = None,
                 mesh=None) -> ServeReport:
    """Timed static rebatching: groups of ``batch`` in arrival order.

    Each group barriers on its last arrival, pads every prompt to the
    group max, prefills together, and decodes until the *slowest*
    member finishes (retired rows burn rounds).  Early arrivals pay the
    barrier in TTFT; short generations pay the group tail in latency —
    the two costs the slot engine's continuous admission removes.
    """
    if cfg.family not in ("dense", "vlm"):
        raise NotImplementedError("serve_static pads to the group max "
                                  "prompt length; dense/vlm only")
    spec = sampling or SamplingSpec()
    clock = clock if clock is not None else WallClock()
    metrics = metrics if metrics is not None else ServeMetrics()
    def ctx():
        return use_sharding(mesh) if mesh is not None \
            else contextlib.nullcontext()

    key = jax.random.PRNGKey(spec.seed)
    nsample = 0

    def sample(lg):
        nonlocal nsample
        nsample += 1
        return sample_token(lg, jax.random.fold_in(key, nsample),
                            temperature=spec.temperature, top_k=spec.top_k)

    ordered = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    rounds = 0
    step = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))
    for g0 in range(0, len(ordered), batch):
        group = ordered[g0:g0 + batch]
        clock.wait_until(max(r.arrival_s for r in group))
        now = clock.now()
        for r in group:
            r.admit_s = now
        maxlen = max(r.prompt_len for r in group)
        toks = jnp.asarray(
            [r.prompt + [0] * (maxlen - r.prompt_len) for r in group],
            jnp.int32)
        last_pos = jnp.asarray([r.prompt_len - 1 for r in group], jnp.int32)
        with ctx():
            b = {"embeds": params["embed"][toks]} \
                if cfg.input_mode == "embeds" else {"tokens": toks}
            logits, state = prefill(params, cfg, b,
                                    extra_capacity=cache_len - maxlen,
                                    last_pos=last_pos)
            tok = sample(logits)
        clock.charge("prefill", maxlen * len(group))
        now = clock.now()
        host = jax.device_get(tok)
        for i, r in enumerate(group):
            r.first_token_s = now
            t = int(host[i])
            r.out_tokens.append(t)
            if eos_id is not None and t == eos_id:
                r.finish_reason = "eos"
            elif len(r.out_tokens) >= r.max_new_tokens:
                r.finish_reason = "length"
            if r.done:
                r.finish_s = now
                metrics.complete(r)
        while any(not r.done for r in group):
            with ctx():
                logits, state = step(params, state, tok)
                tok = sample(logits)
            clock.charge("decode")
            rounds += 1
            now = clock.now()
            host = jax.device_get(tok)
            for i, r in enumerate(group):
                if r.done:
                    continue
                t = int(host[i])
                r.out_tokens.append(t)
                if eos_id is not None and t == eos_id:
                    r.finish_reason = "eos"
                elif len(r.out_tokens) >= r.max_new_tokens:
                    r.finish_reason = "length"
                if r.done:
                    r.finish_s = now
                    metrics.complete(r)
    return ServeReport(metrics.summary(), ordered, rounds, 0)
