"""Slot engine: continuous batching over a fixed-shape decode batch.

The decode batch is a fixed array of ``slots`` rows sharing one jitted
``decode_step`` — per-slot KV segments, per-slot positions
(:func:`repro.models.init_decode_state` with ``per_slot_pos=True``).
Requests are prefilled one at a time (batch-1) at a *bucketed* prompt
length and scattered into a free row by the single jitted
:func:`repro.models.insert_decode_state`; retirement (EOS or token
budget) frees the row and zeroes it (:func:`repro.models.evict_decode_state`).
The compile set is therefore O(#buckets) prefills + one insert + one
decode + one evict for the engine's whole lifetime — slot reuse never
recompiles.

Bucketing is family-aware: dense/vlm prompts are right-padded to the
next power-of-two bucket (causal attention makes the real prefix's
computation independent of trailing pads, and the padded cache rows
stay masked until decode overwrites them — exact, not approximate).
MoE (capacity-limited routing: pads compete with real tokens for
expert slots) and ssm/hybrid (recurrent state absorbs pads) prefill at
exact prompt length instead — one compile per distinct length, still
batch-1.  Audio (encoder-decoder) is not served here.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist import use_sharding
from ..models import (decode_step, evict_decode_state, init_decode_state,
                      insert_decode_state, prefill)
from ..models.common import ArchConfig
from .request import Request
from .sampling import SamplingSpec, sample_token

Array = jax.Array


def bucket_len(plen: int, cache_len: int, *, exact: bool) -> int:
    """Padded prefill length for a prompt of ``plen`` tokens."""
    if exact:
        return plen
    b = 8
    while b < plen:
        b *= 2
    return min(b, cache_len)


class SlotEngine:
    """Continuous batching over ``slots`` fixed-shape decode rows.

    The engine is clock-free: it moves tokens, the scheduler stamps
    time.  ``decode_round`` advances every row one token (inactive rows
    compute garbage that is ignored and overwritten on insert — the
    price of a fixed shape, and why there is no recompilation), returns
    the requests that retired this round.
    """

    def __init__(self, params, cfg: ArchConfig, *, slots: int,
                 cache_len: int, sampling: Optional[SamplingSpec] = None,
                 eos_id: Optional[int] = None, mesh=None):
        if cfg.family == "audio":
            raise NotImplementedError(
                "serve: audio (encoder-decoder) requests need per-request "
                "encoder features; not supported by the slot engine")
        if cfg.sliding_window > 0:
            raise NotImplementedError(
                "serve: sliding-window ring caches are sized by prompt "
                "length at prefill and cannot be slot-inserted; serve "
                "with linear caches")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.sampling = sampling or SamplingSpec()
        self.eos_id = eos_id
        self.mesh = mesh
        # exact-length prefill where right-padding is unsound (see module
        # docstring); power-of-two buckets otherwise
        self._exact_len = cfg.family not in ("dense", "vlm")

        with self._ctx():
            self.state = init_decode_state(cfg, slots, cache_len,
                                           per_slot_pos=True)
            self.last_tok = jnp.zeros((slots,), jnp.int32)
        self.active: list[Optional[Request]] = [None] * slots
        self.free_slots: list[int] = list(range(slots))

        spec = self.sampling
        self._key = jax.random.PRNGKey(spec.seed)
        self._nsample = 0
        self._sample = jax.jit(lambda lg, k: sample_token(
            lg, k, temperature=spec.temperature, top_k=spec.top_k))
        self._insert = jax.jit(insert_decode_state)
        self._evict = jax.jit(evict_decode_state)
        self._decode = jax.jit(
            lambda p, st, t: decode_step(p, cfg, st, t))
        self._prefill_cache: dict[int, object] = {}

    # -- plumbing ----------------------------------------------------------

    def _ctx(self):
        return use_sharding(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()

    def _next_key(self) -> Array:
        self._nsample += 1
        return jax.random.fold_in(self._key, self._nsample)

    def _prefill_fn(self, bucket: int):
        fn = self._prefill_cache.get(bucket)
        if fn is None:
            extra = self.cache_len - bucket
            cfg = self.cfg

            def run(p, toks, last_pos):
                if cfg.input_mode == "embeds":
                    batch = {"embeds": p["embed"][toks]}
                else:
                    batch = {"tokens": toks}
                return prefill(p, cfg, batch, extra_capacity=extra,
                               last_pos=last_pos)

            fn = self._prefill_cache[bucket] = jax.jit(run)
        return fn

    # -- capacity ----------------------------------------------------------

    @property
    def has_free(self) -> bool:
        return bool(self.free_slots)

    @property
    def active_count(self) -> int:
        return self.slots - len(self.free_slots)

    # -- lifecycle ---------------------------------------------------------

    def insert(self, req: Request) -> int:
        """Prefill ``req`` into a free slot; returns its first token.

        The prompt is padded to its bucket, prefilled at batch 1 with
        ``last_pos`` pointing at the real last token, and scattered into
        the slot row.  The first generated token is sampled from the
        prefill logits (so TTFT is one prefill, not prefill + a round).
        """
        if not self.free_slots:
            raise RuntimeError("no free slot")
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {req.rid}: {req.prompt_len}+{req.max_new_tokens} "
                f"tokens exceed cache_len={self.cache_len}")
        slot = self.free_slots.pop(0)
        bucket = bucket_len(req.prompt_len, self.cache_len,
                            exact=self._exact_len)
        toks = jnp.asarray(req.prompt + [0] * (bucket - req.prompt_len),
                           jnp.int32)[None, :]
        with self._ctx():
            logits, one = self._prefill_fn(bucket)(
                self.params, toks, jnp.int32(req.prompt_len - 1))
            tok = self._sample(logits, self._next_key())
            self.state = self._insert(self.state, one, slot)
            self.last_tok = self.last_tok.at[slot].set(tok[0])
        first = int(tok[0])
        req.slot = slot
        req.out_tokens.append(first)
        self.active[slot] = req
        if self._check_retire(req, first):
            self._retire(req)
        return first

    def _check_retire(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            req.finish_reason = "eos"
            return True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _retire(self, req: Request) -> None:
        slot = req.slot
        with self._ctx():
            self.state = self._evict(self.state, slot)
        self.active[slot] = None
        self.free_slots.append(slot)

    def decode_round(self) -> list[Request]:
        """Advance every slot one token; returns requests retired now."""
        if self.active_count == 0:
            return []
        with self._ctx():
            logits, self.state = self._decode(self.params, self.state,
                                              self.last_tok)
            tok = self._sample(logits, self._next_key())
            self.last_tok = tok
        toks = jax.device_get(tok)
        finished = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            t = int(toks[slot])
            req.out_tokens.append(t)
            if self._check_retire(req, t):
                self._retire(req)
                finished.append(req)
        return finished


def static_generate(params, cfg: ArchConfig, requests: list[Request], *,
                    cache_len: int, sampling: Optional[SamplingSpec] = None,
                    eos_id: Optional[int] = None, mesh=None) -> list[Request]:
    """Static rebatching reference: one batch, everyone starts together.

    Prompts are right-padded to the batch max (dense/vlm only — the
    same soundness argument as bucketing), prefilled with a per-request
    ``last_pos`` vector, then decoded with per-slot positions until
    *every* request finishes — retired rows keep burning decode rounds,
    which is exactly the inefficiency continuous batching removes.
    Clock-free: the scheduler's static lane does its own timed loop;
    this is the parity reference.  Mutates and returns ``requests``.
    """
    if cfg.family not in ("dense", "vlm"):
        raise NotImplementedError(
            "static_generate pads to the batch max prompt length, which "
            "is only sound for dense/vlm")
    spec = sampling or SamplingSpec()
    ctx = use_sharding(mesh) if mesh is not None else contextlib.nullcontext()
    b = len(requests)
    maxlen = max(r.prompt_len for r in requests)
    key = jax.random.PRNGKey(spec.seed)
    nsample = 0

    def sample(lg):
        nonlocal nsample
        nsample += 1
        return sample_token(lg, jax.random.fold_in(key, nsample),
                            temperature=spec.temperature, top_k=spec.top_k)

    toks = jnp.asarray(
        [r.prompt + [0] * (maxlen - r.prompt_len) for r in requests],
        jnp.int32)
    last_pos = jnp.asarray([r.prompt_len - 1 for r in requests], jnp.int32)
    batch = {"tokens": toks}
    with ctx:
        if cfg.input_mode == "embeds":
            batch = {"embeds": params["embed"][toks]}
        logits, state = prefill(params, cfg, batch,
                                extra_capacity=cache_len - maxlen,
                                last_pos=last_pos)
        tok = sample(logits)
        first = jax.device_get(tok)
        for i, r in enumerate(requests):
            r.out_tokens.append(int(first[i]))
            if eos_id is not None and int(first[i]) == eos_id:
                r.finish_reason = "eos"
            elif len(r.out_tokens) >= r.max_new_tokens:
                r.finish_reason = "length"
        step = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))
        while any(not r.done for r in requests):
            logits, state = step(params, state, tok)
            tok = sample(logits)
            host = jax.device_get(tok)
            for i, r in enumerate(requests):
                if r.done:
                    continue
                t = int(host[i])
                r.out_tokens.append(t)
                if eos_id is not None and t == eos_id:
                    r.finish_reason = "eos"
                elif len(r.out_tokens) >= r.max_new_tokens:
                    r.finish_reason = "length"
    return requests
