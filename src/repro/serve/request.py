"""Request layer: arrivals, the admission queue, synthetic workloads.

Requests arrive staggered in time with heterogeneous prompt lengths —
the workload shape that breaks ``launch/serve.py``'s old static batch
(everyone starts together, one shared length).  The queue orders by
arrival time; :class:`AdmissionPolicy` rejects requests that can never
fit a slot (prompt + generation exceeds the slot's KV capacity) and
bounds queue depth so overload sheds load instead of growing latency
without bound.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Optional


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle timestamps.

    ``arrival_s`` is set by the workload; the scheduler stamps
    ``admit_s`` (slot granted), ``first_token_s`` (prefill's sampled
    token — the TTFT endpoint) and ``finish_s`` (retirement).  All
    stamps share one :class:`~repro.serve.scheduler.ServeClock` so SLO
    metrics are exact on a synthetic clock and honest on a wall clock.
    """
    rid: int
    prompt: list[int]
    max_new_tokens: int
    arrival_s: float = 0.0
    # -- runtime (filled by the engine/scheduler) --
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    finish_reason: Optional[str] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.finish_reason is not None


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Static admissibility: capacity fit + bounded queue depth.

    ``cache_len`` is the per-slot KV capacity; a request whose prompt
    plus generation budget cannot fit is rejected outright (it would
    otherwise occupy a slot forever).  ``max_queue = 0`` means
    unbounded.
    """
    cache_len: int
    max_queue: int = 0

    def admit(self, req: Request, queued: int) -> bool:
        if req.prompt_len < 1:
            return False
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            return False
        if self.max_queue and queued >= self.max_queue:
            return False
        return True


class RequestQueue:
    """Arrival-ordered queue: requests become *ready* at ``arrival_s``.

    ``pop_ready(now)`` yields the earliest-arrived ready request (FIFO
    among ready; ties broken by rid), or None.  ``next_arrival_s``
    tells the scheduler when to wake an idle round.
    """

    def __init__(self, policy: Optional[AdmissionPolicy] = None):
        self.policy = policy
        self._heap: list[tuple[float, int, Request]] = []
        self.rejected: list[Request] = []

    def push(self, req: Request) -> bool:
        if self.policy is not None and not self.policy.admit(
                req, len(self._heap)):
            req.finish_reason = "rejected"
            self.rejected.append(req)
            return False
        heapq.heappush(self._heap, (req.arrival_s, req.rid, req))
        return True

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._heap and self._heap[0][0] <= now:
            return heapq.heappop(self._heap)[2]
        return None

    def next_arrival_s(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


def synthetic_requests(n: int, *, vocab_size: int, prompt_len: int = 32,
                       prompt_jitter: int = 0, max_new_tokens: int = 16,
                       arrival_gap_s: float = 0.0, seed: int = 0
                       ) -> list[Request]:
    """Deterministic staggered workload: ``n`` requests, prompts of
    ``prompt_len ± prompt_jitter`` random tokens, arrivals spaced
    ``arrival_gap_s`` apart (request i arrives at ``i * gap``).
    """
    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        lo = max(1, prompt_len - prompt_jitter)
        hi = prompt_len + prompt_jitter
        plen = rng.randint(lo, hi)
        prompt = [rng.randrange(vocab_size) for _ in range(plen)]
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=max_new_tokens,
                            arrival_s=i * arrival_gap_s))
    return reqs
