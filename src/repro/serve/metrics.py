"""SLO metrics plane: TTFT, TPOT, latency, throughput, train loss.

Per-request records are derived purely from the scheduler's clock
stamps on :class:`~repro.serve.request.Request`, so on a
:class:`~repro.serve.scheduler.SyntheticClock` every metric is an exact
arithmetic consequence of the configured op costs — testable to the
digit — while a :class:`~repro.serve.scheduler.WallClock` gives honest
wall-time SLOs.  Records stream through the repo-wide
:class:`repro.metrics.MetricsLogger` JSONL when a path is given.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from ..metrics import MetricsLogger
from .request import Request


def request_record(req: Request) -> dict:
    """SLO record for a finished request.

    TTFT is arrival -> first sampled token (queueing + prefill); TPOT
    is the mean inter-token time over the remaining tokens; latency is
    arrival -> retirement.
    """
    n = len(req.out_tokens)
    ttft = req.first_token_s - req.arrival_s
    tpot = ((req.finish_s - req.first_token_s) / (n - 1)) if n > 1 else 0.0
    return {
        "rid": req.rid,
        "prompt_len": req.prompt_len,
        "out_tokens": n,
        "ttft_s": ttft,
        "tpot_s": tpot,
        "latency_s": req.finish_s - req.arrival_s,
        "queue_s": req.admit_s - req.arrival_s,
        "finish_reason": req.finish_reason,
    }


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class ServeMetrics:
    """Accumulates per-request SLO records and per-round train metrics."""

    def __init__(self, logger: Optional[MetricsLogger] = None):
        self.logger = logger
        self.requests: list[dict] = []
        self.train_losses: list[float] = []
        self._first_arrival: Optional[float] = None
        self._last_finish: Optional[float] = None

    def complete(self, req: Request) -> dict:
        rec = request_record(req)
        self.requests.append(rec)
        a, f = req.arrival_s, req.finish_s
        self._first_arrival = a if self._first_arrival is None \
            else min(self._first_arrival, a)
        self._last_finish = f if self._last_finish is None \
            else max(self._last_finish, f)
        if self.logger is not None:
            self.logger.log(req.rid, kind="request", **{
                k: v for k, v in rec.items() if k != "rid"})
        return rec

    def train_step(self, epoch: int, loss: float, **extra: Any) -> None:
        self.train_losses.append(float(loss))
        if self.logger is not None:
            self.logger.log(epoch, kind="train", loss=float(loss), **extra)

    def summary(self) -> dict:
        """p50/p99 SLOs + aggregate throughput over the serving span."""
        ttft = [r["ttft_s"] for r in self.requests]
        tpot = [r["tpot_s"] for r in self.requests]
        lat = [r["latency_s"] for r in self.requests]
        toks = sum(r["out_tokens"] for r in self.requests)
        span = 0.0
        if self._first_arrival is not None:
            span = max(self._last_finish - self._first_arrival, 1e-9)
        out = {
            "n_requests": len(self.requests),
            "total_tokens": toks,
            "span_s": span,
            "tokens_per_s": toks / span if span else 0.0,
            "ttft_p50_s": _pct(ttft, 50), "ttft_p99_s": _pct(ttft, 99),
            "tpot_p50_s": _pct(tpot, 50), "tpot_p99_s": _pct(tpot, 99),
            "latency_p50_s": _pct(lat, 50), "latency_p99_s": _pct(lat, 99),
            "train_epochs": len(self.train_losses),
        }
        if self.train_losses:
            out["train_loss_first"] = self.train_losses[0]
            out["train_loss_last"] = self.train_losses[-1]
        return out
