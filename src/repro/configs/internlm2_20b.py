"""InternLM2-20B [arXiv:2403.17297] — dense GQA kv=8."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="internlm2-20b", family="dense", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384,
    vocab_size=92544,
    # (512, 1024) flash chunking: (1024, 1024) regressed the train_4k
    # collective term for this arch (see EXPERIMENTS.md §Perf cross-arch
    # sweep) — chunk/seq-shard alignment is arch-dependent.
    q_chunk=512, kv_chunk=1024)

SMOKE = ArchConfig(
    name="internlm2-20b-smoke", family="dense", num_layers=2, d_model=256,
    num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
    q_chunk=64, kv_chunk=64)
