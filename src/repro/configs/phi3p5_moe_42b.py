"""Phi-3.5-MoE [hf:microsoft/Phi-3.5-MoE-instruct] — 16 experts top-2."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=6400, vocab_size=32064,
    num_experts=16, experts_per_token=2,
    # (512, 1024) flash chunking: (1024, 1024) regressed the train_4k
    # collective term for this arch (see EXPERIMENTS.md §Perf cross-arch
    # sweep) — chunk/seq-shard alignment is arch-dependent.
    q_chunk=512, kv_chunk=1024)

SMOKE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b-smoke", family="moe", num_layers=2,
    d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=64,
    vocab_size=512, num_experts=4, experts_per_token=2, q_chunk=64,
    kv_chunk=64)
