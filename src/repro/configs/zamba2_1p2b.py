"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, attn_every=6)

SMOKE = ArchConfig(
    name="zamba2-1.2b-smoke", family="hybrid", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    ssm_state=16, attn_every=2, q_chunk=64, kv_chunk=64)
