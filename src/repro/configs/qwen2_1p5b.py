"""Qwen2-1.5B [arXiv:2407.10671] — dense GQA kv=2, QKV bias."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960,
    vocab_size=151936, qkv_bias=True)

SMOKE = ArchConfig(
    name="qwen2-1.5b-smoke", family="dense", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    qkv_bias=True, q_chunk=64, kv_chunk=64)
