"""Whisper-base [arXiv:2212.04356] — enc-dec; mel/conv frontend STUBBED.

``input_specs`` provides precomputed 1500-frame encoder embeddings; this
config covers the transformer backbone (6L encoder + 6L decoder)."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="whisper-base", family="audio", num_layers=6, d_model=512,
    num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=51865, vocab_pad_to=51968,
    encoder_layers=6, encoder_seq=1500,
    # (512, 1024) flash chunking: (1024, 1024) regressed the train_4k
    # collective term for this arch (see EXPERIMENTS.md §Perf cross-arch
    # sweep) — chunk/seq-shard alignment is arch-dependent.
    q_chunk=512, kv_chunk=1024)

SMOKE = ArchConfig(
    name="whisper-base-smoke", family="audio", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    encoder_layers=2, encoder_seq=32, q_chunk=64, kv_chunk=64)
