"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE 128 experts top-8, GQA kv=4."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=4, head_dim=128, d_ff=768,
    vocab_size=151936, qk_norm=True, num_experts=128, experts_per_token=8)

SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=64, vocab_size=512,
    qk_norm=True, num_experts=4, experts_per_token=2, q_chunk=64, kv_chunk=64)
