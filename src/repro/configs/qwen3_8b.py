"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=12288,
    vocab_size=151936, qk_norm=True)

SMOKE = ArchConfig(
    name="qwen3-8b-smoke", family="dense", num_layers=2, d_model=256,
    num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
    qk_norm=True, q_chunk=64, kv_chunk=64)
