"""RWKV6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent decay."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, head_dim=64, d_ff=8960, vocab_size=65536,
    # 40 heads don't divide the 16-way model axis (2.5 heads/chip forces
    # per-token state all-gathers at head boundaries); pad to 48 = 3/chip.
    head_pad_to=48)

SMOKE = ArchConfig(
    name="rwkv6-3b-smoke", family="ssm", num_layers=2, d_model=128,
    num_heads=2, num_kv_heads=2, head_dim=64, d_ff=256, vocab_size=512,
    q_chunk=64, kv_chunk=64)
