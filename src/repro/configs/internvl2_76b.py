"""InternVL2-76B [arXiv:2404.16821] — InternViT STUBBED; InternLM2-76B backbone.

``input_specs`` provides pre-projected patch+token embeddings (B, S, d);
this config covers the language/decoder transformer that consumes them."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="internvl2-76b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=28672,
    vocab_size=128256, input_mode="embeds",
    # (512, 1024) flash chunking: (1024, 1024) regressed the train_4k
    # collective term for this arch (see EXPERIMENTS.md §Perf cross-arch
    # sweep) — chunk/seq-shard alignment is arch-dependent.
    q_chunk=512, kv_chunk=1024)

SMOKE = ArchConfig(
    name="internvl2-76b-smoke", family="vlm", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    input_mode="embeds", q_chunk=64, kv_chunk=64)
