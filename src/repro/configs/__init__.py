"""Architecture + input-shape registry (assigned pool, see DESIGN.md §4)."""
from __future__ import annotations

import dataclasses

from ..models.common import ArchConfig
from . import (command_r_plus_104b, internlm2_20b, internvl2_76b,
               phi3p5_moe_42b, qwen2_1p5b, qwen3_8b, qwen3_moe_30b_a3b,
               rwkv6_3b, whisper_base, zamba2_1p2b)

_MODULES = {
    "qwen3-8b": qwen3_8b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "command-r-plus-104b": command_r_plus_104b,
    "internlm2-20b": internlm2_20b,
    "zamba2-1.2b": zamba2_1p2b,
    "whisper-base": whisper_base,
    "rwkv6-3b": rwkv6_3b,
    "phi3.5-moe-42b-a6.6b": phi3p5_moe_42b,
    "qwen2-1.5b": qwen2_1p5b,
    "internvl2-76b": internvl2_76b,
}

ARCH_NAMES = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

SWA_WINDOW = 4096   # sliding-window width for long-context attention variant


def get_config(name: str, *, shape: str | None = None) -> ArchConfig:
    """Full config; with ``shape='long_500k'`` attention archs get the SWA
    variant (sub-quadratic requirement — SSM families are natively O(1))."""
    cfg = _MODULES[name].FULL
    if shape == "long_500k" and cfg.family != "ssm":
        cfg = dataclasses.replace(cfg, sliding_window=SWA_WINDOW)
    return cfg


def smoke_config(name: str) -> ArchConfig:
    # smoke variants execute on CPU, whose runtime lacks BF16xBF16=F32 dot
    # support — disable the TPU MXU f32-accumulation policy there.
    return dataclasses.replace(_MODULES[name].SMOKE, mxu_f32_accum=False)
