"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias."""
from ..models.common import ArchConfig

FULL = ArchConfig(
    name="command-r-plus-104b", family="dense", num_layers=64, d_model=12288,
    num_heads=96, num_kv_heads=8, head_dim=128, d_ff=33792,
    vocab_size=256000,
    # (512, 1024) flash chunking: (1024, 1024) regressed the train_4k
    # collective term for this arch (see EXPERIMENTS.md §Perf cross-arch
    # sweep) — chunk/seq-shard alignment is arch-dependent.
    q_chunk=512, kv_chunk=1024)

SMOKE = ArchConfig(
    name="command-r-plus-104b-smoke", family="dense", num_layers=2,
    d_model=256, num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
    vocab_size=512, q_chunk=64, kv_chunk=64)
