"""``repro.api`` — the public programmatic surface of the system.

The paper's contract — a fixed compute time T producing variable
per-worker minibatches b_i(t), followed by a fixed consensus window T_c —
is configured by three frozen, JSON-round-trippable specs and driven by
one session object:

  * :class:`TrainSpec` / :class:`ClockSpec` / :class:`ConsensusSpec`
    (:mod:`repro.api.specs`) — declarative configuration with argparse
    and JSON adapters.
  * :class:`Clock` with :class:`SimulatedClock` (paper evaluation) and
    :class:`MeasuredClock` (hardware-tracking) implementations
    (:mod:`repro.api.clock`) — yields ``(times, budget)`` per epoch.
  * :class:`TrainProtocol` / :func:`build_protocol`
    (:mod:`repro.api.protocol`) — the uniform TrainState + epoch driver
    over the exact / gossip / quantized / pipelined modes.
  * :class:`AMBSession` (:mod:`repro.api.session`) — mesh + params +
    clock + protocol behind ``step`` / ``flush`` / ``save`` / ``params``,
    with elastic worker membership via ``set_active``.
  * :class:`ControllerSpec` (:mod:`repro.api.specs`) — opt-in online
    self-tuning: the session feeds per-epoch telemetry to a
    :class:`repro.control.Controller`, which retunes the budget T
    (online Lemma 6), the async staleness D with its damping gamma, and
    the effective batch target, applied mid-run without restart.

``launch/train.py``, ``launch/serve.py``, ``launch/dryrun.py`` and
``benchmarks/dist_step.py`` are thin adapters over this package; see
``examples/api_session.py`` for programmatic use.
"""
from .clock import Clock, MeasuredClock, SimulatedClock, make_clock  # noqa: F401
from .protocol import (AsyncProtocol, ExactProtocol,                 # noqa: F401
                       GossipProtocol, PipelinedProtocol, TrainProtocol,
                       build_protocol)
from .session import AMBSession                                      # noqa: F401
from .specs import (ClockSpec, ConsensusSpec, ControllerSpec,        # noqa: F401
                    TrainSpec)

__all__ = [
    "AMBSession", "AsyncProtocol", "Clock", "ClockSpec", "ConsensusSpec",
    "ControllerSpec", "ExactProtocol", "GossipProtocol", "MeasuredClock",
    "PipelinedProtocol", "SimulatedClock", "TrainProtocol", "TrainSpec",
    "build_protocol", "make_clock",
]
