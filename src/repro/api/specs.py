"""Frozen, JSON-round-trippable configuration for the Session API.

Three orthogonal specs describe an AMB deployment, subsuming the drivers'
former argparse flags and the ad-hoc ``AMBConfig`` plumbing:

  * :class:`TrainSpec` — *what* trains and *where*: architecture, mesh
    extents (pod x data x model), optimizer, AMB-vs-FMB mode, seed.
  * :class:`ClockSpec` — the paper's fixed-compute-time contract: the
    straggler model, the budget T (explicit, or Lemma 6 when ``None`` —
    an explicit ``compute_time=0.0`` is honoured, never treated as unset),
    the consensus window T_c, and measured-vs-simulated timing.
  * :class:`ConsensusSpec` — *how* workers agree: strategy name, gossip
    graph/rounds, pipelining, and the dual-averaging beta schedule.

Every spec round-trips through JSON (``to_json`` / ``from_json``) and
through argparse (``add_cli_args`` / ``from_args``), so a CLI invocation,
a JSON job file, and a programmatic :class:`repro.api.AMBSession` all
construct the identical configuration.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Optional, Tuple

from ..core.dual_averaging import BetaSchedule
from ..core.stragglers import (Deterministic, ShiftedExponential,
                               StragglerModel)

OPTIMIZERS = ("dual_averaging", "adamw", "sgd")
MODES = ("amb", "fmb")
CLOCK_KINDS = ("measured", "simulated")
STRAGGLER_MODELS = ("shifted_exp", "deterministic")
GRAPHS = ("ring", "torus")


class _Spec:
    """Shared JSON round-trip for the frozen spec dataclasses."""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "_Spec":
        kw = dict(d)
        for f in dataclasses.fields(cls):
            # JSON has no tuples; restore them (torus_shape, active masks)
            if f.name in kw and isinstance(kw[f.name], list):
                kw[f.name] = tuple(kw[f.name])
        return cls(**kw)

    @classmethod
    def from_json(cls, s: str) -> "_Spec":
        return cls.from_dict(json.loads(s))

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# TrainSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainSpec(_Spec):
    """Architecture, mesh, optimizer — the *what/where* of a session."""

    arch: str = "qwen2-1.5b"
    smoke: bool = False               # reduced (CPU-friendly) config variant
    seq_len: int = 256
    batch_per_worker: int = 8         # b/n: target per-worker minibatch
    data: int = 1                     # mesh extents; workers = pod * data
    model: int = 1
    pod: int = 1
    optimizer: str = "dual_averaging"
    mode: str = "amb"                 # amb | fmb
    seed: int = 0
    kernels: str = "auto"             # kernel routing: auto | pallas | ref
                                      # | pallas_interpret (repro.kernels.
                                      # router; auto = Pallas on TPU/GPU,
                                      # jnp ref on CPU)
    redundancy: int = 1               # rho: coded data replication factor
                                      # (repro.dist.redundancy — groups of
                                      # rho workers share rotated copies of
                                      # one data block; 1 = uncoded)

    @staticmethod
    def add_cli_args(ap: argparse.ArgumentParser) -> None:
        ap.add_argument("--arch", default=TrainSpec.arch)
        ap.add_argument("--smoke", action="store_true",
                        help="use the reduced config (CPU-friendly)")
        ap.add_argument("--seq-len", type=int, default=TrainSpec.seq_len)
        ap.add_argument("--batch-per-worker", type=int,
                        default=TrainSpec.batch_per_worker)
        ap.add_argument("--data", type=int, default=TrainSpec.data)
        ap.add_argument("--model", type=int, default=TrainSpec.model)
        ap.add_argument("--pod", type=int, default=TrainSpec.pod)
        ap.add_argument("--optimizer", default=TrainSpec.optimizer,
                        choices=list(OPTIMIZERS))
        ap.add_argument("--mode", default=TrainSpec.mode,
                        choices=list(MODES))
        ap.add_argument("--seed", type=int, default=TrainSpec.seed)
        from ..kernels.router import MODES as KERNEL_MODES
        ap.add_argument("--kernels", default=TrainSpec.kernels,
                        choices=list(KERNEL_MODES),
                        help="kernel backend routing: auto picks compiled "
                             "Pallas on TPU/GPU and the jnp reference on "
                             "CPU (interpret mode never runs on the hot "
                             "path unless forced)")
        ap.add_argument("--redundancy", type=int,
                        default=TrainSpec.redundancy,
                        help="coded data replication factor rho (must "
                             "divide the worker count): groups of rho "
                             "workers hold rotated copies of one data "
                             "block and decode-on-settle weights keep the "
                             "gradient estimate unbiased under worker "
                             "loss; 1 = uncoded")

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "TrainSpec":
        return cls(arch=args.arch, smoke=args.smoke, seq_len=args.seq_len,
                   batch_per_worker=args.batch_per_worker, data=args.data,
                   model=args.model, pod=args.pod, optimizer=args.optimizer,
                   mode=args.mode, seed=args.seed,
                   kernels=getattr(args, "kernels", TrainSpec.kernels),
                   redundancy=getattr(args, "redundancy",
                                      TrainSpec.redundancy))


# ---------------------------------------------------------------------------
# ClockSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClockSpec(_Spec):
    """The fixed-time contract: straggler model, budget T, window T_c.

    ``compute_time`` is *tri-state*: ``None`` derives the Lemma-6 budget
    ``T = (1 + n/b) mu`` (from the model's mean, or from the measured
    per-gradient EMA under ``kind="measured"``); any float — including an
    explicit ``0.0`` — is the budget verbatim.  The old drivers' ``x or
    default`` idiom silently discarded ``--compute-time 0.0``; every
    consumer of this spec must use ``is None`` checks (see
    :meth:`resolve_budget`).
    """

    kind: str = "measured"            # measured | simulated
    compute_time: Optional[float] = None   # explicit T; None = Lemma 6
    comm_time: float = 0.5            # consensus window T_c (sim seconds)
    straggler: str = "shifted_exp"    # shifted_exp | deterministic
    lam: float = 2.0 / 3.0            # ShiftedExponential rate (paper I.2)
    zeta: float = 1.0                 # ShiftedExponential shift
    grad_time: float = 1.0            # Deterministic per-gradient time
    ema: float = 0.7                  # measured-clock EMA smoothing

    def make_model(self, b_ref: int) -> StragglerModel:
        """The configured straggler model at reference batch ``b_ref``."""
        if self.straggler == "shifted_exp":
            return ShiftedExponential(lam=self.lam, zeta=self.zeta,
                                      b_ref=b_ref)
        if self.straggler == "deterministic":
            return Deterministic(grad_time=self.grad_time, b_ref=b_ref)
        raise ValueError(f"unknown straggler model {self.straggler!r}; "
                         f"choose from {STRAGGLER_MODELS}")

    def resolve_budget(self, derived: float) -> float:
        """Explicit T when set (0.0 included), else the derived budget."""
        return derived if self.compute_time is None else self.compute_time

    @staticmethod
    def add_cli_args(ap: argparse.ArgumentParser) -> None:
        ap.add_argument("--clock", default=ClockSpec.kind,
                        choices=list(CLOCK_KINDS),
                        help="b_i(t) source: measured per-step wall time "
                             "(mesh default) or the simulated straggler "
                             "clock (paper evaluation)")
        ap.add_argument("--sim-clock", action="store_true",
                        help="alias for --clock simulated")
        ap.add_argument("--compute-time", type=float, default=None,
                        help="AMB budget T; default from Lemma 6 "
                             "(an explicit 0.0 is honoured)")
        ap.add_argument("--comm-time", type=float,
                        default=ClockSpec.comm_time)
        ap.add_argument("--straggler", default=ClockSpec.straggler,
                        choices=list(STRAGGLER_MODELS))
        ap.add_argument("--clock-ema", type=float, default=ClockSpec.ema,
                        help="measured-clock EMA smoothing factor")

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ClockSpec":
        kind = "simulated" if getattr(args, "sim_clock", False) \
            else args.clock
        return cls(kind=kind, compute_time=args.compute_time,
                   comm_time=args.comm_time, straggler=args.straggler,
                   ema=getattr(args, "clock_ema", ClockSpec.ema))


# ---------------------------------------------------------------------------
# ConsensusSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConsensusSpec(_Spec):
    """Consensus strategy + epoch driver (sequential / pipelined / async).

    ``pipeline`` is the hardcoded staleness-1 overlap;
    ``async_epochs`` + ``staleness`` generalize it to AMB-DG
    bounded-staleness delayed-gradient epochs (``staleness`` in-flight
    consensus payloads).  The two drivers are mutually exclusive.
    """

    consensus: str = "exact"          # exact | gossip | gossip_q8 | gossip_q4
    graph: str = "ring"               # worker gossip graph
    gossip_rounds: int = 5            # r (fp32-equivalent budget)
    torus_shape: Optional[Tuple[int, int]] = None  # default: mesh extents
    lazy: float = 0.5                 # lazy-Metropolis mixing (PSD P)
    pipeline: bool = False            # staleness-1 pipelined epochs
    async_epochs: bool = False        # AMB-DG bounded-staleness epochs
    staleness: int = 1                # D: in-flight consensus payloads
    radius: Optional[float] = None    # prox trust-region (paper eq. 7)
    beta_k: float = 50.0              # BetaSchedule knobs; beta_mu=None
    beta_mu: Optional[float] = None   # defaults to the global batch b
    beta_scale: float = 200.0

    def beta(self, global_batch: int) -> BetaSchedule:
        mu = float(global_batch) if self.beta_mu is None else self.beta_mu
        return BetaSchedule(k=self.beta_k, mu=mu, scale=self.beta_scale)

    def to_amb_config(self, global_batch: int, seed: int = 0,
                      active: Optional[tuple] = None,
                      noise_stats: bool = False, redundancy: int = 1,
                      relayout: bool = True):
        """The dist-layer :class:`repro.dist.amb.AMBConfig` equivalent."""
        from ..dist.amb import AMBConfig
        return AMBConfig(consensus=self.consensus,
                         gossip_rounds=self.gossip_rounds, graph=self.graph,
                         torus_shape=self.torus_shape, lazy=self.lazy,
                         beta=self.beta(global_batch), radius=self.radius,
                         seed=seed, active=active, noise_stats=noise_stats,
                         redundancy=redundancy, relayout=relayout)

    @staticmethod
    def add_cli_args(ap: argparse.ArgumentParser) -> None:
        from ..dist.consensus import CONSENSUS_CHOICES
        ap.add_argument("--consensus", default=ConsensusSpec.consensus,
                        choices=list(CONSENSUS_CHOICES),
                        help="exact weighted all-reduce, decentralized "
                             "gossip with per-worker dual replicas, or "
                             "8/4-bit quantized gossip (more rounds per "
                             "T_c)")
        ap.add_argument("--graph", default=ConsensusSpec.graph,
                        choices=list(GRAPHS),
                        help="worker gossip graph; torus follows the "
                             "physical (pod, data) mesh extents")
        ap.add_argument("--gossip-rounds", type=int,
                        default=ConsensusSpec.gossip_rounds)
        ap.add_argument("--pipeline", action="store_true",
                        help="staleness-1 pipelined epochs: overlap each "
                             "step's gossip with the next forward/backward")
        ap.add_argument("--async", dest="async_epochs", action="store_true",
                        help="AMB-DG delayed-gradient epochs: consensus "
                             "settles asynchronously with bounded "
                             "staleness (--staleness); generalizes "
                             "--pipeline beyond staleness 1")
        ap.add_argument("--staleness", type=int,
                        default=ConsensusSpec.staleness,
                        help="D: number of in-flight consensus payloads "
                             "under --async (1 = the pipelined schedule)")

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ConsensusSpec":
        return cls(consensus=args.consensus, graph=args.graph,
                   gossip_rounds=args.gossip_rounds,
                   pipeline=args.pipeline,
                   async_epochs=args.async_epochs,
                   staleness=args.staleness)


# ---------------------------------------------------------------------------
# ControllerSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ControllerSpec(_Spec):
    """Online self-tuning of budget T, staleness D, and batch target b.

    When ``enabled``, :class:`repro.api.AMBSession` feeds each epoch's
    telemetry (measured per-gradient rates, consensus/compute ratio,
    gradient-noise scale) into a :class:`repro.control.Controller`, which
    re-solves the Lemma-6 budget, retunes the AMB-DG staleness bound
    ``D`` (and its damping ``gamma = 1/(2D)``), and grows the effective
    per-worker minibatch target as gradient noise shrinks.  Decisions are
    rate-limited (``max_step``), deadbanded (``deadband``), hysteretic
    (``hysteresis``), and only issued every ``interval`` epochs after
    ``warmup`` epochs of pure observation.
    """

    enabled: bool = False
    interval: int = 5                 # epochs between decisions
    warmup: int = 5                   # observe-only epochs before deciding
    ema: float = 0.8                  # telemetry EMA smoothing
    budget: bool = True               # retune T (Lemma 6, online)
    staleness: bool = True            # retune D / gamma (AMB-DG, async only)
    batch: bool = True                # grow b target with the noise scale
    d_max: int = 8                    # staleness ceiling
    hysteresis: float = 0.25          # D-change hysteresis (in T_c/T units)
    deadband: float = 0.1             # min relative budget change to act
    max_step: float = 2.0             # max budget change factor per decision

    @staticmethod
    def add_cli_args(ap: argparse.ArgumentParser) -> None:
        ap.add_argument("--controller", action="store_true",
                        help="enable the online self-tuning controller "
                             "(budget T, staleness D, batch target)")
        ap.add_argument("--controller-interval", type=int,
                        default=ControllerSpec.interval,
                        help="epochs between controller decisions")
        ap.add_argument("--controller-warmup", type=int,
                        default=ControllerSpec.warmup,
                        help="observe-only epochs before the first decision")
        ap.add_argument("--controller-dmax", type=int,
                        default=ControllerSpec.d_max,
                        help="staleness ceiling for the controller")

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ControllerSpec":
        return cls(enabled=getattr(args, "controller", False),
                   interval=getattr(args, "controller_interval",
                                    ControllerSpec.interval),
                   warmup=getattr(args, "controller_warmup",
                                  ControllerSpec.warmup),
                   d_max=getattr(args, "controller_dmax",
                                 ControllerSpec.d_max))
