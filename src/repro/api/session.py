"""``AMBSession`` — the one programmatic surface over train / serve / bench.

A session owns everything the drivers used to hand-wire: mesh setup, param
init + sharding, clock construction, consensus-strategy and epoch-driver
selection (via :func:`repro.api.protocol.build_protocol`), and the uniform
``TrainState``.  The same four calls work identically across the exact,
gossip, quantized-gossip, and pipelined modes:

    session = AMBSession(TrainSpec(arch="qwen2-1.5b", smoke=True, data=4,
                                   model=2),
                         ClockSpec(kind="simulated"),
                         ConsensusSpec(consensus="gossip", graph="torus"))
    metrics = session.run(steps)          # prefetched data plane
    session.flush()                       # settle in-flight consensus
    session.save("ckpt/")                 # primal checkpoint, any mode
    w = session.params                    # current primal iterate

    ``run`` feeds the session from an :class:`repro.data.InputSource`
    (default: :meth:`batch_source`, per-worker shards of the arch's LM
    token stream) through a background :class:`repro.data.Prefetcher`,
    overlapping epoch t's device step with epoch t+1's host build +
    transfer.  ``step(batch)`` remains the single-epoch primitive for
    callers that hand-build batches.  The jitted step/flush donate the
    TrainState (``donate_argnums=0``): every protocol's output state
    leaf aliases its input leaf, so the old iterate's buffers are
    reused in place instead of briefly doubling resident memory.

Elastic worker membership is first-class: ``session.set_active(mask)``
exploits AMB's existing b_i(t) = 0 tolerance — a masked worker's
minibatch is forced to zero (so its sequence weights vanish from the
eq.-6 average) and the gossip operator is rebuilt over the survivors —
ring/torus fleets relayout onto a smaller ring/torus whose taps stay on
the collective-permute fast path
(:func:`repro.dist.consensus.survivor_taps`; non-circulant graphs fall
back to the dense :func:`repro.dist.consensus.masked_metropolis`).  The
TrainState carries over untouched across membership changes: a
rejoining worker resumes from its (stale) dual replica and consensus
re-mixes it in.  :meth:`run`'s ``faults=`` hook drives a
:class:`repro.faults.FaultModel` through this machinery epoch by epoch,
and ``TrainSpec.redundancy`` adds coded data placement so the gradient
estimate stays unbiased while workers are down
(:mod:`repro.dist.redundancy`).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import load_checkpoint, save_checkpoint
from ..configs import get_config, smoke_config
from ..control import Controller, EpochRecord
from ..core.stragglers import amb_batch_sizes, fmb_finish_times
from ..data import Prefetcher, StreamSource, put_batch
from ..data.pipeline import LMTokenStream
from ..dist import use_sharding
from ..dist.amb import num_workers
from ..dist.params import tree_shardings
from ..kernels import router
from ..launch.mesh import make_host_mesh
from ..metrics import MetricsLogger
from ..models import init_params
from ..optim import make_optimizer
from .clock import make_clock
from .protocol import build_protocol
from .specs import ClockSpec, ConsensusSpec, ControllerSpec, TrainSpec

Array = jax.Array


def _unalias(state):
    """Break object aliasing between TrainState leaves.

    ``donate_argnums`` requires every donated buffer to appear exactly
    once in the arguments, but freshly-*initialized* states can hold one
    array under two leaves (e.g. fp32 params, where the dual-averaging
    ``opt["w0"] = params.astype(f32)`` no-op returns ``params`` itself)
    — stepping such a state donates the buffer twice and XLA rejects the
    execute.  Copying the repeat occurrences once at assembly restores
    the protocols' aliasing contract; stepped states are always
    alias-free (each output leaf owns its buffer).
    """
    seen: set = set()

    def u(x):
        if isinstance(x, jax.Array):
            if id(x) in seen:
                return jnp.copy(x)
            seen.add(id(x))
        return x

    return jax.tree.map(u, state)


class AMBSession:
    """One AMB training/serving session over a device mesh.

    Args:
      train: architecture / mesh / optimizer spec.
      clock: fixed-time contract spec (measured or simulated b_i(t)).
      consensus: consensus strategy + epoch driver spec.
      mesh: an existing mesh to run on; default builds a host mesh from
        ``train``'s (pod, data, model) extents.
      params: pre-initialized (e.g. restored) parameters; default
        initializes from ``train.seed`` and shards per the layout rules.
      cfg: an explicit :class:`repro.models.common.ArchConfig`, for
        custom architectures outside the registry (tests, research).
      controller: a :class:`repro.api.specs.ControllerSpec`; when
        ``enabled``, every ``step`` feeds a telemetry record to a
        :class:`repro.control.Controller` and applies its actions
        in-place — budget into the :class:`~repro.api.clock.Clock`,
        staleness by drain-and-rebuild (:meth:`_apply_staleness`) — no
        restart, no recompile beyond the new driver graph.
      metrics_path: optional JSONL path; when set, every epoch (and
        every controller decision) is appended via
        :class:`repro.metrics.MetricsLogger`.

    A zero-step session is a well-defined no-op: construction alone
    yields valid ``params`` (the initialization), ``flush`` and ``save``
    work, and no loss is ever fabricated.
    """

    def __init__(self, train: TrainSpec,
                 clock: Optional[ClockSpec] = None,
                 consensus: Optional[ConsensusSpec] = None,
                 controller: Optional[ControllerSpec] = None, *,
                 mesh=None, params=None, cfg=None, metrics_path=None):
        self.train = train
        if train.kernels != "auto":
            # pin the kernel routing for the process (logged once by the
            # router); "auto" leaves any ambient REPRO_KERNELS in force
            router.set_mode(train.kernels)
        self.clock_spec = clock if clock is not None else ClockSpec()
        self.consensus_spec = consensus if consensus is not None \
            else ConsensusSpec()
        self.cfg = cfg if cfg is not None else (
            smoke_config(train.arch) if train.smoke
            else get_config(train.arch))
        self.mesh = mesh if mesh is not None else make_host_mesh(
            train.data, train.model, pod=train.pod)
        self.n_workers = num_workers(self.mesh)
        self.global_batch = self.n_workers * train.batch_per_worker
        self._batch_axes = tuple(a for a in ("pod", "data")
                                 if a in self.mesh.axis_names)
        # coded redundancy: validated here (fail at construction, not in
        # the first step) — the same CodedAssignment drives both the data
        # placement (batch_source) and the decode weights (dist steps)
        self._assignment = None
        if train.redundancy > 1:
            from ..dist.redundancy import CodedAssignment
            self._assignment = CodedAssignment(self.n_workers,
                                               train.redundancy)
        self._slow: Optional[np.ndarray] = None   # fault-injected slowdowns

        self.clock = make_clock(self.clock_spec, self.n_workers,
                                train.batch_per_worker)
        self._decentralized = (self.consensus_spec.pipeline
                               or self.consensus_spec.async_epochs
                               or self.consensus_spec.consensus != "exact")
        self._optimizer = None
        if not self._decentralized:
            if train.optimizer == "dual_averaging":
                self._optimizer = make_optimizer(
                    "dual_averaging",
                    beta=self.consensus_spec.beta(self.global_batch))
            else:
                self._optimizer = make_optimizer(train.optimizer)
        elif train.optimizer != "dual_averaging":
            raise ValueError("gossip / pipelined / async modes run the "
                             "paper's dual-averaging protocol; use "
                             "optimizer='dual_averaging'")

        self.controller_spec = controller if controller is not None \
            else ControllerSpec()
        self.controller: Optional[Controller] = None
        if self.controller_spec.enabled:
            self.controller = Controller(
                self.controller_spec, n_workers=self.n_workers,
                comm_time=self.clock_spec.comm_time,
                b_target=self.global_batch, b_cap=self.global_batch,
                staleness=self.consensus_spec.staleness,
                async_mode=self.consensus_spec.async_epochs)
        self.metrics = MetricsLogger(metrics_path) if metrics_path \
            else None

        self._key = jax.random.PRNGKey(train.seed)
        self._active: Optional[tuple] = None
        self._protocols: dict = {}       # (mask, staleness) -> protocol
        self._build_protocol()

        with use_sharding(self.mesh):
            if params is None:
                params = init_params(self._key, self.cfg)
                params = jax.tree.map(
                    lambda p, sh: jax.device_put(p, sh), params,
                    tree_shardings(params, self.mesh))
            self.state = _unalias(self.protocol.init(params))
        self.steps_done = 0
        self.sim_wall = 0.0

    # -- construction ------------------------------------------------------

    def _build_protocol(self, active: Optional[tuple] = None) -> None:
        """(Re)build the epoch driver; at init, on set_active, and on a
        controller staleness retune.

        Exact consensus ignores ``active`` at the step level (a masked
        worker's b_i = 0 already zeroes it out of the eq.-6 average), so
        only the gossip-family protocols rebuild — and rebuilds are
        cached by ``(mask, staleness)``, so a worker rejoining a
        previously-seen configuration — or the controller swinging D
        back to an earlier value — reuses the warm jitted executable
        instead of recompiling.
        """
        mask = active if self._decentralized else None
        key = (mask, self.consensus_spec.staleness) \
            if self._decentralized else None
        if key not in self._protocols:
            amb = self.consensus_spec.to_amb_config(
                self.global_batch, self.train.seed, active=mask,
                noise_stats=self.controller is not None,
                redundancy=self.train.redundancy)
            proto = build_protocol(
                self.cfg, self.mesh, amb, optimizer=self._optimizer,
                pipeline=self.consensus_spec.pipeline,
                async_epochs=self.consensus_spec.async_epochs,
                staleness=self.consensus_spec.staleness)
            # donate the TrainState: every protocol's output state leaf
            # aliases its input leaf (shape/dtype/sharding — the
            # contract repro.api.protocol documents), so XLA rewrites
            # the iterate in place instead of holding old + new
            # parameter/dual/queue buffers live across the update
            self._protocols[key] = (
                proto, jax.jit(proto.step, donate_argnums=0),
                jax.jit(proto.flush, donate_argnums=0))
        self.protocol, self._step_fn, self._flush_fn = self._protocols[key]

    # -- elastic membership ------------------------------------------------

    @property
    def active(self) -> np.ndarray:
        """Bool (n_workers,) membership mask (all True when fully manned)."""
        if self._active is None:
            return np.ones(self.n_workers, dtype=bool)
        return np.asarray(self._active, dtype=bool)

    def set_active(self, mask) -> None:
        """Elastic worker join/leave: re-mask b_i(t), rebuild gossip taps.

        ``mask`` is a length-``n_workers`` boolean sequence.  A False
        worker contributes b_i(t) = 0 every epoch (its sequence weights
        vanish — the paper's straggler-wipeout case, which AMB already
        tolerates) and is cut out of the gossip graph; ring/torus
        fleets re-lay the survivors onto a smaller ring/torus (taps
        stay collective-permutes), other graphs re-derive dense
        Metropolis weights on the induced subgraph.  A single survivor
        degenerates to identity consensus; an all-inactive mask is
        rejected before any state is touched.  The TrainState (params /
        dual replicas) is preserved, so a later ``set_active`` that
        re-admits the worker resumes it from its stale dual and lets
        consensus pull it back in.

        In-flight consensus is **drained first** (pipelined / async
        modes): a queued payload was packed for the *old* membership's
        gossip operator, so it settles under the operator it was
        enqueued against before the taps rebuild.  The drain is a plain
        ``flush`` — always a valid state transition — so a subsequently
        rejected mask (e.g. one that disconnects the gossip graph) still
        leaves the session in a consistent, merely-settled state.
        """
        mask = np.asarray(mask, dtype=bool).reshape(-1)
        if mask.shape[0] != self.n_workers:
            raise ValueError(f"mask has {mask.shape[0]} entries for "
                             f"{self.n_workers} workers")
        if not mask.any():
            raise ValueError("at least one worker must stay active")
        active = None if mask.all() else tuple(bool(m) for m in mask)
        if active != self._active:
            self.flush()     # drain in-flight rounds under the old operator
        # build first, commit second: a rejected mask must leave the
        # session unchanged (modulo the always-valid drain above)
        self._build_protocol(active)
        self._active = active

    def set_slowdown(self, slow) -> None:
        """Pin per-worker slowdown multipliers on the clock draws.

        ``slow`` is a length-``n_workers`` sequence of per-gradient-time
        multipliers (or None to clear): each epoch's straggler-model
        draws are scaled per worker *before* the deadline cut, so a
        fail-slow worker's b_i(t) shrinks through the paper's own
        variable-minibatch mechanism — no special-casing downstream.
        Composes multiplicatively with the configured
        :class:`repro.core.stragglers.StragglerModel`.
        """
        if slow is None:
            self._slow = None
            return
        slow = np.asarray(slow, dtype=np.float64).reshape(-1)
        if slow.shape[0] != self.n_workers:
            raise ValueError(f"slowdown has {slow.shape[0]} entries for "
                             f"{self.n_workers} workers")
        if (slow <= 0).any():
            raise ValueError("slowdown multipliers must be positive")
        self._slow = None if np.all(slow == 1.0) else slow

    # -- the epoch ---------------------------------------------------------

    def epoch_sizes(self, times: Array, budget: float) -> Array:
        """b_i(t) for one epoch: deadline cut + membership mask."""
        if self.train.mode == "amb":
            b = amb_batch_sizes(times, budget)
        else:
            b = jnp.full((self.n_workers,), self.train.batch_per_worker,
                         jnp.int32)
        if self._active is not None:
            b = jnp.where(jnp.asarray(self.active), b, 0)
        return b

    def step(self, batch, b: Optional[Array] = None) -> dict:
        """Run one AMB epoch on a (host) global batch; returns metrics.

        ``batch`` is the unsharded global batch (leading dim
        ``global_batch``); the session shards it over the worker axes.
        ``b`` overrides the clock-derived per-worker minibatch sizes
        (sized ``(n_workers,)``); by default the clock draws this epoch's
        per-gradient times and the deadline T decides b_i(t).
        """
        with use_sharding(self.mesh):
            skey = jax.random.fold_in(self._key, 10_000 + self.steps_done)
            times, budget = self.clock.epoch(skey)
            if self._slow is not None:
                # fault-injected degradation: scale each worker's
                # per-gradient times; the deadline cut below turns the
                # slowdown into a smaller b_i(t) automatically
                times = times * jnp.asarray(self._slow,
                                            times.dtype)[:, None]
            if b is None:
                b = self.epoch_sizes(times, budget)
            # simulated wall clock: pipelined epochs hide T_c under the
            # next epoch's compute; async epochs give each consensus D
            # compute windows, so only T_c/D must fit per epoch; FMB
            # waits for the slowest worker
            if self.train.mode == "amb":
                spec = self.consensus_spec
                if spec.async_epochs:
                    self.sim_wall += max(
                        float(budget),
                        self.clock_spec.comm_time / spec.staleness)
                elif spec.pipeline:
                    self.sim_wall += max(float(budget),
                                         self.clock_spec.comm_time)
                else:
                    self.sim_wall += (float(budget)
                                      + self.clock_spec.comm_time)
            else:
                self.sim_wall += float(jnp.max(fmb_finish_times(
                    times, self.train.batch_per_worker))) \
                    + self.clock_spec.comm_time
            batch = put_batch(batch, self.mesh, self._batch_axes)
            t0 = time.time()
            self.state, m = self._step_fn(self.state, batch, b)
            loss = float(m["loss"])
            step_s = time.time() - t0
            self.clock.update(step_s, float(m["global_batch"]))
            self.steps_done += 1
            out = {"loss": loss,
                   "global_batch": float(m["global_batch"]),
                   "budget_s": float(budget),
                   "step_s": step_s,
                   "sim_wall_s": self.sim_wall,
                   "staleness": self.consensus_spec.staleness,
                   "b": np.asarray(b)}
            if self.controller is not None:
                action = self._control(m, out, b, times)
                if action is not None:
                    out["action"] = action.to_dict()
            if self.metrics is not None:
                self.metrics.log(self.steps_done,
                                 **{k: v for k, v in out.items()
                                    if k != "b"})
            return out

    def batch_source(self) -> StreamSource:
        """The session's default input: per-worker shards of the arch's
        LM token stream (worker i draws stream node i — distinct i.i.d.
        shards, deterministic in (seed, node, epoch) so restores resume
        the exact remaining stream).  Under coded redundancy the
        session's :class:`repro.dist.redundancy.CodedAssignment` places
        rotated copies of each group's block instead (group members
        share a stream node)."""
        return StreamSource(
            LMTokenStream(vocab_size=self.cfg.vocab_size,
                          seq_len=self.train.seq_len,
                          seed=self.train.seed),
            self.n_workers, self.train.batch_per_worker,
            assignment=self._assignment)

    def run(self, steps: int, source=None, *, prefetch: int = 2,
            on_step=None, faults=None) -> Optional[dict]:
        """Run ``steps`` epochs fed by ``source`` through the prefetched
        data plane; returns the last epoch's metrics (None at 0 steps).

        ``source`` is any :class:`repro.data.InputSource` (default:
        :meth:`batch_source`).  With ``prefetch >= 1`` a background
        :class:`repro.data.Prefetcher` keeps that many batches
        device-resident ahead of the consumer — epochs are drawn from
        the source at absolute indices ``steps_done .. steps_done +
        steps``, so a restored session continues the data order where
        the saved one stopped.  ``prefetch=0`` is the synchronous
        baseline (build, put, then step — the pre-dataplane behavior,
        kept for A/B timing).  ``on_step(epoch, metrics)`` is called
        after every epoch with the 0-based absolute index of the epoch
        that just ran (``steps_done`` has already advanced past it).

        ``faults`` is a :class:`repro.faults.FaultModel` (or a prebuilt
        :class:`repro.faults.FaultInjector`) applied *before* each
        epoch: membership changes go through :meth:`set_active` (which
        drains any in-flight async consensus first), slowdowns through
        :meth:`set_slowdown`.  The fault trajectory is a pure function
        of the epoch index, so a restored session under the same model
        replays it exactly.  Note the data plane keeps over-provisioning
        every worker's slots — a downed worker's samples are simply
        zero-weighted (or, under coded redundancy, re-covered by its
        group peers).
        """
        if steps <= 0:
            return None
        if source is None:
            source = self.batch_source()
        injector = None
        if faults is not None:
            from ..faults import FaultInjector
            injector = faults if isinstance(faults, FaultInjector) \
                else FaultInjector(faults)
        out = None
        if prefetch < 1:
            for epoch in range(self.steps_done, self.steps_done + steps):
                if injector is not None:
                    injector.apply(self, epoch)
                out = self.step(source.batch(epoch))
                if on_step is not None:
                    on_step(self.steps_done - 1, out)
            return out
        pf = Prefetcher(source, self.mesh, self._batch_axes,
                        depth=prefetch, start_epoch=self.steps_done,
                        steps=steps)
        try:
            for batch in pf:
                # the prefetcher yields epochs in order from steps_done,
                # so the incoming batch's epoch IS the current counter
                if injector is not None:
                    injector.apply(self, self.steps_done)
                out = self.step(batch)
                if on_step is not None:
                    on_step(self.steps_done - 1, out)
        finally:
            pf.close()
        return out

    def _control(self, m: dict, out: dict, b: Array, times: Array):
        """Feed the epoch to the controller; apply any action in-place."""
        # measured mean per-gradient seconds, from the time each node
        # *actually spent* on the gradients it finished — exact even when
        # b_i saturates the data cap and the node idles out the window
        # (the naive T / b_i would over-bill those nodes and turn the
        # Lemma-6 re-solve into a positive feedback loop)
        tnp, bnp = np.asarray(times), np.asarray(b)
        eff = np.minimum(bnp, tnp.shape[1])
        done = eff >= 1
        tau_s = None
        if done.any():
            elapsed = np.cumsum(tnp, axis=1)[np.arange(tnp.shape[0]),
                                             np.maximum(eff, 1) - 1]
            tau_s = float(np.mean(elapsed[done] / eff[done]))
        rec = EpochRecord(
            t=self.steps_done, budget_s=out["budget_s"],
            comm_time_s=self.clock_spec.comm_time, step_s=out["step_s"],
            loss=out["loss"], b=bnp, tau_s=tau_s,
            global_batch=out["global_batch"],
            staleness=self.consensus_spec.staleness
            if self.consensus_spec.async_epochs else 1,
            grad_sq_norm=(float(m["grad_sq_norm"])
                          if "grad_sq_norm" in m else None),
            grad_var=float(m["grad_var"]) if "grad_var" in m else None)
        action = self.controller.observe(rec)
        if action is None:
            return None
        if action.budget is not None:
            self.clock.set_budget(action.budget)
        if action.staleness is not None:
            self._apply_staleness(action.staleness)
        # a b_target move needs no actuation here: it feeds the next
        # Lemma-6 re-solve, so the batch is driven through the deadline T
        return action

    def flush(self) -> None:
        """Settle in-flight consensus (pipelined mode); no-op otherwise."""
        with use_sharding(self.mesh):
            self.state = self._flush_fn(self.state)

    def _apply_staleness(self, staleness: int) -> None:
        """Retune the async driver's D mid-run: drain, rebuild, migrate.

        The in-flight queue is **drained first** (a plain ``flush``, the
        same move :meth:`set_active` makes): every queued payload was
        packed with the *old* D's damping gamma and must settle under
        the operator it was enqueued against.  The new driver then
        starts from an empty queue — the settled dual ``z`` and the
        epoch counter ``t`` carry over, the ``staleness``-shaped queue
        (and snapshot) leaves are re-initialized to the flushed-empty
        zeros.  Rebuilds go through the same ``(mask, staleness)``
        protocol cache as :meth:`set_active`, so revisiting a D reuses
        the warm executable.
        """
        if staleness == self.consensus_spec.staleness:
            return
        if not self.consensus_spec.async_epochs:
            raise ValueError("staleness is the async driver's knob; this "
                             "session runs "
                             f"{self.protocol.mode!r}")
        self.flush()    # settle the queue under the D it was packed for
        self.consensus_spec = self.consensus_spec.replace(
            staleness=int(staleness))
        self._build_protocol(self._active)
        with use_sharding(self.mesh):
            fresh = self.protocol.init(self.state["w0"])
            fresh["z"] = self.state["z"]
            fresh["w0"] = self.state["w0"]
            fresh["t"] = self.state["t"]
            self.state = _unalias(fresh)

    def close(self) -> None:
        """Release the metrics logger (idempotent)."""
        if self.metrics is not None:
            self.metrics.close()
            self.metrics = None

    # -- the iterate -------------------------------------------------------

    @property
    def params(self):
        """The current primal iterate, identical across modes.

        Exact mode: the optimizer's parameters.  Gossip modes: the
        node-averaged prox of the dual replicas
        (:func:`repro.dist.amb.gossip_primal`).  Pipelined sessions
        should ``flush()`` first so the last enqueued message is folded
        in.
        """
        with use_sharding(self.mesh):
            return self.protocol.primal(self.state)

    def save(self, directory) -> None:
        """Checkpoint the primal + full TrainState at the current step.

        Layout: ``<dir>/step_<n>/`` keeps the primal-only public layout
        (what ``launch/serve`` style consumers read), and two restore
        companions are written alongside: ``<dir>/session_state/
        step_<n>/`` — the protocol TrainState (optimizer or dual-replica
        state, any in-flight consensus queue, the epoch counter) — and
        ``<dir>/session.json`` — the spec triple plus session counters.
        Together they let :meth:`restore` resume exactly.
        """
        directory = Path(directory)
        save_checkpoint(directory, self.steps_done, self.params)
        state_dir = save_checkpoint(directory / "session_state",
                                    self.steps_done, self.state)
        meta = {
            "step": self.steps_done,
            "sim_wall_s": self.sim_wall,
            "train": self.train.to_dict(),
            "clock": self.clock_spec.to_dict(),
            # NB: consensus_spec reflects the *current* staleness (the
            # controller may have retuned D), so a restore rebuilds the
            # driver whose queue shapes match the checkpointed state
            "consensus": self.consensus_spec.to_dict(),
            "active": None if self._active is None else list(self._active),
            "sec_per_grad": getattr(self.clock, "sec_per_grad", None),
            # the budget actually in force (controller actions pin it)
            "clock_budget": getattr(
                self.clock, "budget_t",
                getattr(self.clock, "compute_time", None)),
            "controller": None if self.controller is None else {
                "spec": self.controller_spec.to_dict(),
                "state": self.controller.to_state()},
        }
        blob = json.dumps(meta, sort_keys=True, indent=1)
        # per-step copy first: counters/mask must match the state they
        # describe when restore() selects an older step; the root copy
        # names the latest step (the restore default)
        (state_dir / "session.json").write_text(blob)
        (directory / "session.json").write_text(blob)

    @classmethod
    def restore(cls, directory, *, step: Optional[int] = None, mesh=None,
                cfg=None, metrics_path=None) -> "AMBSession":
        """Rebuild a session from a :meth:`save` directory, resuming exactly.

        Recovers the spec triple from ``session.json``, then the full
        TrainState — parameters, optimizer / dual-replica state
        (including any in-flight consensus queue), and the step counter
        — plus the simulated wall clock, the measured-clock EMA, and the
        elastic membership mask.  A restored session continues the
        training trajectory of the saved one step-for-step.

        ``step`` selects a checkpoint (default: the latest, named in the
        root ``session.json``); counters, clock EMA, and the membership
        mask come from that step's own metadata copy, so an older
        checkpoint resumes *its* trajectory, not the latest save's.
        ``mesh`` / ``cfg`` override the rebuilt mesh or architecture
        config (shapes must match the checkpoint — ``cfg`` is required
        when the saved session used a custom one).
        """
        directory = Path(directory)
        meta = json.loads((directory / "session.json").read_text())
        step_sel = meta["step"] if step is None else step
        per_step = (directory / "session_state" / f"step_{step_sel:08d}"
                    / "session.json")
        if per_step.exists():
            meta = json.loads(per_step.read_text())
        ctl = meta.get("controller")
        session = cls(TrainSpec.from_dict(meta["train"]),
                      ClockSpec.from_dict(meta["clock"]),
                      ConsensusSpec.from_dict(meta["consensus"]),
                      None if ctl is None
                      else ControllerSpec.from_dict(ctl["spec"]),
                      mesh=mesh, cfg=cfg, metrics_path=metrics_path)
        if meta.get("active") is not None:
            session.set_active(meta["active"])   # before the state lands:
            # the drain-on-change flush must not touch the restored queue
        state = load_checkpoint(directory / "session_state", step_sel,
                                like=session.state)

        def land(got, cur):
            # re-establish the mesh layout of the freshly-built state;
            # leaves the protocol init left uncommitted (scalars like the
            # epoch counter) must stay uncommitted, or jit refuses to mix
            # them with the mesh-sharded leaves
            if isinstance(cur.sharding, jax.sharding.NamedSharding):
                return jax.device_put(got, cur.sharding)
            return jnp.asarray(got)

        with use_sharding(session.mesh):
            session.state = _unalias(jax.tree.map(land, state,
                                                  session.state))
        session.steps_done = step_sel
        session.sim_wall = float(meta.get("sim_wall_s", 0.0))
        if meta.get("sec_per_grad") is not None \
                and hasattr(session.clock, "sec_per_grad"):
            session.clock.sec_per_grad = float(meta["sec_per_grad"])
        if meta.get("clock_budget") is not None:
            # re-pin the budget that was in force (a controller may have
            # moved it off the spec-derived value); for an unpinned
            # measured clock this key is None and re-derivation survives
            session.clock.set_budget(float(meta["clock_budget"]))
        if ctl is not None and session.controller is not None:
            session.controller.load_state(ctl["state"])
        return session
