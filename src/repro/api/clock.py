"""The epoch clock behind the paper's fixed-compute-time contract.

A :class:`Clock` answers one question per epoch: *given this epoch's PRNG
key, what are the per-gradient times and the compute deadline T?*  From
``(times, budget)`` the session derives the paper's variable minibatch
``b_i(t)`` (:func:`repro.core.stragglers.amb_batch_sizes`) — the entire
straggler-exploitation mechanism reduces to this one interface.

  * :class:`SimulatedClock` — the paper-evaluation clock: times come
    straight from a :class:`repro.core.stragglers.StragglerModel`, and T
    is either explicit or the Lemma-6 ``(1 + n/b) mu``.
  * :class:`MeasuredClock` — the mesh-path default (moved here from
    ``launch/train.py``): the straggler model supplies only the *relative*
    cross-worker heterogeneity, while the absolute seconds-per-gradient
    unit is an EMA of the real measured step time, so b_i(t) tracks the
    actual hardware rate.

Both honour an explicit ``compute_time`` — including ``0.0`` — via
``is None`` checks (:meth:`repro.api.specs.ClockSpec.resolve_budget`);
the budget is never re-derived when the user pinned it.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..core.stragglers import StragglerModel
from .specs import ClockSpec

Array = jax.Array


class Clock:
    """Per-epoch ``(times, budget)`` source.

    ``epoch(key)`` returns the ``(n, b_max)`` per-gradient times and the
    compute budget T for one epoch.  ``update`` feeds back the measured
    wall time of the step that consumed them (a no-op for simulated
    clocks).
    """

    def epoch(self, key: Array) -> Tuple[Array, float]:
        raise NotImplementedError

    def update(self, step_seconds: float, global_b: float) -> None:
        pass

    def set_budget(self, budget: float) -> None:
        """Pin the compute budget T (controller actuation point)."""
        raise NotImplementedError


class SimulatedClock(Clock):
    """Paper-evaluation clock: model times, Lemma-6 (or explicit) T."""

    def __init__(self, model: StragglerModel, n: int,
                 batch_per_worker: int,
                 compute_time: Optional[float] = None):
        self.model = model
        self.n = n
        self.bpw = batch_per_worker
        gb = n * batch_per_worker
        # Lemma 6: T = (1 + n/b) mu (simulated-clock units); an explicit
        # compute_time — 0.0 included — wins (tri-state, not truthiness).
        derived = (1.0 + n / gb) * model.mean_batch_time()
        self.budget_t = derived if compute_time is None else compute_time

    def epoch(self, key: Array) -> Tuple[Array, float]:
        return self.model.per_gradient_times(key, self.n, self.bpw), \
            self.budget_t

    def set_budget(self, budget: float) -> None:
        self.budget_t = float(budget)


class MeasuredClock(Clock):
    """b_i(t) from real per-step wall-clock timings (mesh path default).

    The simulated straggler model keeps one job — supplying the *relative*
    per-worker heterogeneity (its per-gradient draws divided by its own
    mean) — while the absolute seconds-per-gradient unit is an EMA of the
    measured step time divided by the gradients that step consumed.  The
    Lemma-6 budget ``T = (1 + n/b) mu`` is re-derived from the measured
    unit each epoch, so the deadline tracks the actual hardware rate
    (compile-time warmup, cache effects, CPU contention) instead of the
    model's constants.  An explicit ``compute_time`` (0.0 included) pins
    the budget and disables the re-derivation.
    """

    def __init__(self, model: StragglerModel, n: int,
                 batch_per_worker: int, ema: float = 0.7,
                 compute_time: Optional[float] = None):
        self.model = model
        self.n = n
        self.bpw = batch_per_worker
        self.ema = ema
        self.compute_time = compute_time
        # model-relative unit: mean seconds per gradient in model time
        self.model_unit = model.mean_batch_time() / model.b_ref
        self.sec_per_grad = None      # measured EMA; None until first step

    def _unit(self) -> float:
        return self.sec_per_grad if self.sec_per_grad is not None \
            else self.model_unit      # pre-measurement boot

    def update(self, step_seconds: float, global_b: float) -> None:
        obs = step_seconds / max(global_b, 1.0)
        self.sec_per_grad = (obs if self.sec_per_grad is None else
                             self.ema * self.sec_per_grad
                             + (1.0 - self.ema) * obs)

    def times(self, key: Array) -> Array:
        """(n, b_max) per-gradient times in *measured* seconds."""
        rel = self.model.per_gradient_times(key, self.n, self.bpw) \
            / self.model_unit                       # mean-1 heterogeneity
        return rel * self._unit()

    def budget(self) -> float:
        """Lemma-6 T in measured seconds: (1 + n/b) * mu_measured."""
        gb = self.n * self.bpw
        return (1.0 + self.n / gb) * self._unit() * self.bpw

    def epoch(self, key: Array) -> Tuple[Array, float]:
        budget = self.budget() if self.compute_time is None \
            else self.compute_time
        return self.times(key), budget

    def set_budget(self, budget: float) -> None:
        # pinning disables the clock's own Lemma-6 re-derivation — when a
        # controller drives the budget, the controller is the tracker
        self.compute_time = float(budget)


def make_clock(spec: ClockSpec, n: int, batch_per_worker: int) -> Clock:
    """The configured :class:`Clock` for ``n`` workers."""
    model = spec.make_model(batch_per_worker)
    if spec.kind == "simulated":
        return SimulatedClock(model, n, batch_per_worker,
                              compute_time=spec.compute_time)
    if spec.kind == "measured":
        return MeasuredClock(model, n, batch_per_worker, ema=spec.ema,
                             compute_time=spec.compute_time)
    raise ValueError(f"unknown clock kind {spec.kind!r}")
