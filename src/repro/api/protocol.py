"""One ``TrainProtocol`` surface over the exact / gossip / pipelined steps.

The related protocol family (AMB, Anytime SGD, AMB-with-delayed-gradients)
diverges only at the *epoch driver*: how a state advances by one epoch and
how the consensus phase is scheduled against the compute phase.  This
module isolates exactly that layer.  A :class:`TrainProtocol` exposes four
methods over a uniform ``TrainState``:

    ``init(params) -> state``            build the mode's TrainState
    ``step(state, batch, b) -> (state, metrics)``   one AMB epoch
    ``flush(state) -> state``            settle in-flight consensus
    ``primal(state) -> params``          the current primal iterate

The uniform **TrainState** is a pytree dict that always carries the epoch
counter ``"t"``; the mode-specific leaves are documented per protocol:

  * :class:`ExactProtocol` — ``{"params", "opt", "t"}``: the eps = 0 /
    master-worker limit (:func:`repro.dist.amb.make_train_step`), driven
    by any :class:`repro.optim.Optimizer`.
  * :class:`GossipProtocol` — ``{"z", "w0", "t"}``: per-worker dual
    replicas under any :class:`repro.dist.consensus.ConsensusStrategy`
    (:func:`repro.dist.amb.make_gossip_train_step`).
  * :class:`PipelinedProtocol` — ``{"z", "w0", "t", "pending"}``: the
    staleness-1 pipelined epoch
    (:func:`repro.dist.pipeline.make_pipelined_gossip_train_step`);
    ``flush`` settles the final in-flight message.
  * :class:`AsyncProtocol` — ``{"z", "w0", "t", "queue"}``: AMB-DG
    bounded-staleness delayed-gradient epochs
    (:func:`repro.dist.async_epochs.make_async_gossip_train_step`);
    ``queue`` holds the D in-flight consensus payloads and ``flush``
    settles them all in enqueue order.  At ``staleness=1`` the step and
    flush graphs are identical to :class:`PipelinedProtocol`.

:func:`build_protocol` replaces the drivers' former three-way
``if gossip / if pipeline`` branching; launch, serve, dry-run, and the
benchmarks all construct their step through it (directly or via
:class:`repro.api.AMBSession`).

**Donation contract.**  Every protocol's ``step`` and ``flush`` return a
state whose leaves alias the input state's leaves one-for-one in shape,
dtype, and sharding — ``step`` rewrites values, never structure (the
epoch counter increments, queues rotate in place, no leaf appears or
changes layout mid-run).  That invariant is what lets
:class:`repro.api.AMBSession` jit them with ``donate_argnums=0``: XLA
reuses the old TrainState's buffers for the new one instead of holding
parameters, dual replicas, optimizer state, and the in-flight consensus
queue doubly live across the update.  The factories themselves stay
donation-free — callers that reuse a state after stepping (tests, the
benchmarks' repeated-timing loops) jit without donation.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from ..dist.amb import (AMBConfig, gossip_primal, make_gossip_train_step,
                        make_train_step)
from ..dist.async_epochs import make_async_gossip_train_step
from ..dist.pipeline import make_pipelined_gossip_train_step

TrainState = dict      # pytree; always carries "t", see module docstring


class TrainProtocol:
    """Uniform epoch-driver interface (see module docstring)."""

    mode: str = "base"

    def init(self, params) -> TrainState:
        raise NotImplementedError

    def step(self, state: TrainState, batch, b) -> tuple:
        raise NotImplementedError

    def flush(self, state: TrainState) -> TrainState:
        """Settle any in-flight consensus; identity for unpipelined modes."""
        return state

    def primal(self, state: TrainState) -> Any:
        raise NotImplementedError


class ExactProtocol(TrainProtocol):
    """eps = 0 exact consensus, any optimizer.  State: params/opt/t."""

    mode = "exact"

    def __init__(self, cfg, mesh, amb: AMBConfig, optimizer):
        self.optimizer = optimizer
        self._step = make_train_step(cfg, optimizer, mesh, amb)

    def init(self, params) -> TrainState:
        return {"params": params, "opt": self.optimizer.init(params),
                "t": jnp.zeros((), jnp.int32)}

    def step(self, state, batch, b):
        params, opt, metrics = self._step(state["params"], state["opt"],
                                          batch, b)
        return {"params": params, "opt": opt, "t": state["t"] + 1}, metrics

    def primal(self, state):
        return state["params"]


class GossipProtocol(TrainProtocol):
    """Decentralized consensus, per-worker dual replicas.  State: z/w0/t."""

    mode = "gossip"

    def __init__(self, cfg, mesh, amb: AMBConfig):
        self.amb = amb
        self._init, self._step = make_gossip_train_step(cfg, mesh, amb)

    def init(self, params) -> TrainState:
        return self._init(params)

    def step(self, state, batch, b):
        return self._step(state, batch, b)

    def primal(self, state):
        return gossip_primal(state, self.amb)


class PipelinedProtocol(TrainProtocol):
    """Staleness-1 pipelined epochs.  State: z/w0/t/pending."""

    mode = "pipelined"

    def __init__(self, cfg, mesh, amb: AMBConfig):
        self.amb = amb
        self._init, self._step, self._flush = \
            make_pipelined_gossip_train_step(cfg, mesh, amb)

    def init(self, params) -> TrainState:
        return self._init(params)

    def step(self, state, batch, b):
        return self._step(state, batch, b)

    def flush(self, state):
        return self._flush(state)

    def primal(self, state):
        return gossip_primal(state, self.amb)


class AsyncProtocol(TrainProtocol):
    """AMB-DG bounded-staleness epochs.  State: z/w0/t/queue.

    ``queue`` is a length-``staleness`` tuple of in-flight consensus
    payloads, oldest first; each step settles the due head, computes
    delayed gradients at the last settled dual, and enqueues at the
    tail.  ``flush`` drains the whole queue.
    """

    mode = "async"

    def __init__(self, cfg, mesh, amb: AMBConfig, staleness: int = 1):
        self.amb = amb
        self.staleness = staleness
        self._init, self._step, self._flush = \
            make_async_gossip_train_step(cfg, mesh, amb, staleness)

    def init(self, params) -> TrainState:
        return self._init(params)

    def step(self, state, batch, b):
        return self._step(state, batch, b)

    def flush(self, state):
        return self._flush(state)

    def primal(self, state):
        return gossip_primal(state, self.amb)


def build_protocol(cfg, mesh, amb: AMBConfig, *, optimizer=None,
                   pipeline: bool = False, async_epochs: bool = False,
                   staleness: int = 1) -> TrainProtocol:
    """The right :class:`TrainProtocol` for (consensus, driver, optimizer).

    ``pipeline=True``, ``async_epochs=True``, or a non-exact consensus
    selects the decentralized dual-averaging family (per-worker
    replicas); exact consensus without either driver runs the
    single-program weighted step under ``optimizer``.  ``async_epochs``
    generalizes ``pipeline`` to a bounded-staleness in-flight queue of
    ``staleness`` consensus payloads (AMB-DG); the two drivers are
    mutually exclusive.  Elastic membership rides on ``amb.active`` (a
    worker bool mask): the gossip operator is rebuilt on the induced
    active subgraph — the hook behind
    :meth:`repro.api.AMBSession.set_active`.
    """
    from ..optim import DualAveragingOpt
    if pipeline and async_epochs:
        raise ValueError("--pipeline is the hardcoded staleness-1 driver; "
                         "--async generalizes it — choose one (async with "
                         "staleness 1 is the pipelined schedule)")
    if staleness != 1 and not async_epochs:
        raise ValueError(f"staleness={staleness} is the async driver's "
                         "knob; pass --async (async_epochs=True) — "
                         "without it the staleness would be silently "
                         "ignored")
    decentralized = pipeline or async_epochs or amb.consensus != "exact"
    if decentralized and optimizer is not None and \
            not isinstance(optimizer, DualAveragingOpt):
        raise ValueError("gossip / pipelined / async modes run the paper's "
                         "dual-averaging protocol; use the dual_averaging "
                         "optimizer")
    if async_epochs:
        return AsyncProtocol(cfg, mesh, amb, staleness)
    if pipeline:
        return PipelinedProtocol(cfg, mesh, amb)
    if amb.consensus != "exact":
        return GossipProtocol(cfg, mesh, amb)
    if optimizer is None:
        optimizer = DualAveragingOpt(beta=amb.beta, radius=amb.radius)
    return ExactProtocol(cfg, mesh, amb, optimizer)
