"""Beyond-paper benchmarks: pipelined AMB, quantized gossip, adaptive-T.

Each returns a dict recorded in EXPERIMENTS.md §Perf (beyond-paper half).
The paper-faithful AMB numbers in paper_figs.py are the baselines.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (BetaSchedule, EngineConfig, ShiftedExponential,
                        amb_budget_from_fmb, run_amb)
from repro.core.extensions import (AdaptiveBudget, run_amb_adaptive,
                                   run_amb_pipelined, run_amb_quantized)
from repro.core.objectives import LinearRegression

from .paper_figs import _time_to_error


def _linreg_setup(n=10, b_global=600, d=256):
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(42), (d,))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    t = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t, comm_time=0.3 * t,
        fmb_batch_per_node=b_global // n, graph="paper",
        consensus_rounds=5, beta=BetaSchedule(k=1.0, mu=float(b_global)))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    return obj, w_star, model, cfg, eval_fn


def ext_pipelined_amb() -> dict:
    """Overlap consensus with compute (staleness-1): extra samples at zero
    wall-time cost.  The gain scales with T_c/T (the fraction of the epoch
    the paper leaves idle): reported for the paper's ratio (0.3) and a
    comm-heavy cluster (T_c = T), where harvested samples ~ double the
    batch."""
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    kw = dict(epochs=120, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_amb = run_amb(obj, model, cfg, **kw)
    h_pipe = run_amb_pipelined(obj, model, cfg, **kw)

    # comm-heavy regime: T_c = T
    import dataclasses
    cfg_h = dataclasses.replace(cfg, comm_time=cfg.compute_time,
                                b_max=8 * (600 // cfg.n))
    h_amb_h = run_amb(obj, model, cfg_h, **kw)
    h_pipe_h = run_amb_pipelined(obj, model, cfg_h, **kw)
    lah = np.asarray(h_amb_h.eval_loss)
    lph = np.asarray(h_pipe_h.eval_loss)
    mid_h = slice(5, len(lah) // 2)
    # time-to-target is quantized by epoch boundaries (identical epoch
    # times), so a loose target ties; compare at a strict target plus the
    # regime-free metrics: per-epoch loss dominance and regret at equal
    # wall time.
    la = np.asarray(h_amb.eval_loss)
    lp = np.asarray(h_pipe.eval_loss)
    # target in the *descent* phase (AMB's loss at 1/3 of the run): both
    # schemes are still improving there, so time-to-target discriminates.
    target = float(la[len(la) // 3])
    t_amb = _time_to_error(h_amb, target)
    t_pipe = _time_to_error(h_pipe, target)
    mid = slice(5, len(la) // 2)      # pre-floor phase
    return dict(
        t_amb=t_amb, t_pipe=t_pipe,
        # epoch-boundary quantization ties this at 1.0 for the paper's
        # T_c/T; the per-epoch metrics below are the discriminating ones.
        speedup_strict_target=t_amb / t_pipe if t_pipe > 0 else float("nan"),
        batch_amb=float(h_amb.global_batch.mean()),
        batch_pipe=float(h_pipe.global_batch.mean()),
        midrun_loss_ratio=float(la[mid].mean() / lp[mid].mean()),
        epochs_pipe_no_worse=float(np.mean(lp <= la * 1.02)),
        regret_ratio=float(h_amb.regret[-1] / h_pipe.regret[-1]),
        final_amb=float(h_amb.eval_loss[-1]),
        final_pipe=float(h_pipe.eval_loss[-1]),
        # comm-heavy regime (T_c = T): the harvested window ~doubles samples
        batch_gain_comm_heavy=float(h_pipe_h.global_batch.mean() /
                                    h_amb_h.global_batch.mean()),
        midrun_loss_ratio_comm_heavy=float(lah[mid_h].mean() /
                                           lph[mid_h].mean()),
        claim="harvesting comm-window gradients beats paper AMB per-epoch; "
              "gain scales with T_c/T")


def ext_quantized_gossip() -> dict:
    """8-bit stochastic-quantized gossip: 4x rounds in the same T_c."""
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    kw = dict(epochs=80, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    h_fp = run_amb(obj, model, cfg, **kw)
    out = {"eps_fp32_r5": float(h_fp.consensus_eps[5:].mean()),
           "final_fp32": float(h_fp.eval_loss[-1])}
    for bits in (8, 4):
        h_q = run_amb_quantized(obj, model, cfg, bits=bits, **kw)
        out[f"eps_q{bits}_r{int(5 * 32 / bits)}"] = float(
            h_q.consensus_eps[5:].mean())
        out[f"final_q{bits}"] = float(h_q.eval_loss[-1])
    out["eps_reduction_q8"] = out["eps_fp32_r5"] / max(
        out["eps_q8_r20"], 1e-12)
    out["claim"] = "same T_c, lower Lemma-1 eps via quantized rounds"
    return out


def ext_adaptive_budget() -> dict:
    """Non-stationary cluster (3x slowdown at epoch 40): adaptive-T holds
    the global batch at target; fixed-T collapses to ~1/3."""
    obj, w_star, model, cfg, eval_fn = _linreg_setup()
    target = 600

    def model_fn(t):
        if t <= 40:
            return ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
        return ShiftedExponential(lam=2 / 9, zeta=3.0, b_ref=60)

    ctrl = AdaptiveBudget(b_target=target, ema=0.7)
    h_ad = run_amb_adaptive(obj, model_fn, cfg, controller=ctrl, epochs=80,
                            key=jax.random.PRNGKey(0),
                            sample_args=(w_star,), eval_fn=eval_fn,
                            f_star=0.5 * obj.noise_var)
    h_fix_slow = run_amb(obj, model_fn(80), cfg, epochs=40,
                         key=jax.random.PRNGKey(1), sample_args=(w_star,),
                         eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    return dict(
        batch_target=target,
        adaptive_batch_tail=float(h_ad.global_batch[60:].mean()),
        fixed_batch_after_slowdown=float(h_fix_slow.global_batch.mean()),
        batch_recovery=float(h_ad.global_batch[60:].mean()) / target,
        final_adaptive=float(h_ad.eval_loss[-1]),
        claim="online Lemma-6: batch pinned to target under drift")


ALL = {
    "ext_pipelined_amb": ext_pipelined_amb,
    "ext_quantized_gossip": ext_quantized_gossip,
    "ext_adaptive_budget": ext_adaptive_budget,
}
