"""Paper-figure benchmarks: each function reproduces one table/figure claim.

All results are returned as dicts (and printed as CSV by run.py) so
EXPERIMENTS.md can cite them directly.  Wall time is the simulated clock of
the straggler models (App. I methodology); numerics are real.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BetaSchedule, EngineConfig, InducedGroups, PauseModel,
                        ShiftedExponential, amb_budget_from_fmb, run_amb,
                        run_fmb)
from repro.core.objectives import LinearRegression, LogisticRegression
from repro.core.regret import (shifted_exp_asymptotic_ratio,
                               theorem7_ratio)
from repro.core.stragglers import amb_batch_sizes, fmb_finish_times


def _time_to_error(history, target):
    """First simulated wall time at which eval loss <= target."""
    loss = np.asarray(history.eval_loss)
    wall = np.asarray(history.wall_time)
    hit = np.nonzero(loss <= target)[0]
    return float(wall[hit[0]]) if len(hit) else float("inf")


def _speedup_run(obj, sample_args, eval_fn, f_star, model, n, b_global,
                 epochs=120, graph="paper", rounds=5, key=0,
                 target_frac=0.05, calibrate=False):
    # Heterogeneous-group models violate Assumption 1 (identical T_i across
    # nodes); the Lemma-6 closed form then overshoots T.  The paper picks T
    # empirically in those experiments (App. I.4) — `calibrate` reproduces
    # that: bisect T so E[b(T)] ~= b_global.
    if calibrate:
        from repro.core.stragglers import amb_budget_calibrated
        t_budget = amb_budget_calibrated(model, n, b_global)
    else:
        t_budget = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t_budget, comm_time=0.3 * t_budget,
        fmb_batch_per_node=b_global // n, graph=graph,
        consensus_rounds=rounds,
        beta=BetaSchedule(k=1.0, mu=float(b_global)))
    kw = dict(epochs=epochs, key=jax.random.PRNGKey(key),
              sample_args=sample_args, eval_fn=eval_fn, f_star=f_star)
    h_amb = run_amb(obj, model, cfg, **kw)
    h_fmb = run_fmb(obj, model, cfg, **kw)
    l0 = float(h_amb.eval_loss[0])
    lmin = max(float(h_amb.eval_loss[-1]), float(h_fmb.eval_loss[-1]))
    target = lmin + target_frac * (l0 - lmin)
    t_amb = _time_to_error(h_amb, target)
    t_fmb = _time_to_error(h_fmb, target)
    return dict(t_amb=t_amb, t_fmb=t_fmb,
                speedup=t_fmb / t_amb if t_amb > 0 else float("nan"),
                amb_wall=float(h_amb.wall_time[-1]),
                fmb_wall=float(h_fmb.wall_time[-1]),
                mean_b_amb=float(h_amb.global_batch.mean()),
                final_amb=float(h_amb.eval_loss[-1]),
                final_fmb=float(h_fmb.eval_loss[-1]))


def fig1a_linreg_ec2() -> dict:
    """Fig. 1(a): linear regression, fully distributed, natural stragglers.

    Paper: AMB ~25-30% faster wall time to equal error on EC2 (n=10).
    EC2 t2.micro natural variability modelled as shifted exponential.
    """
    d = 512                       # paper: 1e5; scaled for CI wall time
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(42), (d,))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=600)
    out = _speedup_run(obj, (w_star,), eval_fn, 0.5 * obj.noise_var,
                       model, n=10, b_global=600)
    out["paper_claim"] = "FMB ~1.25x slower (25%) on EC2"
    return out


def fig1b_logreg_ec2() -> dict:
    """Fig. 1(b): logistic regression (MNIST-like), fully distributed.

    Paper: AMB ~1.7x faster to equal cost."""
    obj = LogisticRegression(dim=64, num_classes=10)
    means = obj.make_class_means(jax.random.PRNGKey(3))
    eval_batch = obj.sample(jax.random.PRNGKey(9), (2048,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)
    f_star = float(eval_fn(_train_logreg_opt(obj, means)))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=800)
    out = _speedup_run(obj, (means,), eval_fn, f_star, model,
                       n=10, b_global=8000, epochs=100)
    out["paper_claim"] = "AMB ~1.7x faster (Fig 1b)"
    return out


def _train_logreg_opt(obj, means, steps=300):
    """Near-optimal w for F(w*) reference via full-batch gradient descent."""
    key = jax.random.PRNGKey(123)
    batch = obj.sample(key, (4096,), means)
    w = obj.init_w()
    for _ in range(steps):
        w = w - 0.5 * obj.grad(w, batch)
    return w


def fig3_hub_and_spoke() -> dict:
    """Fig. 3: master-worker (hub-and-spoke) topology, n=20 (19 workers).

    AMB with exact consensus (Remark 1: eps=0 master-worker)."""
    obj = LogisticRegression(dim=64, num_classes=10)
    means = obj.make_class_means(jax.random.PRNGKey(5))
    eval_batch = obj.sample(jax.random.PRNGKey(11), (2048,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)
    f_star = float(eval_fn(_train_logreg_opt(obj, means)))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=210)
    n = 19
    b_global = 19 * 210
    t_budget = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=840, chunk=210, compute_time=t_budget,
        comm_time=0.3 * t_budget, fmb_batch_per_node=210, graph="star",
        consensus_mode="exact",
        beta=BetaSchedule(k=1.0, mu=float(b_global)))
    kw = dict(epochs=80, key=jax.random.PRNGKey(0), sample_args=(means,),
              eval_fn=eval_fn, f_star=f_star)
    h_amb = run_amb(obj, model, cfg, **kw)
    h_fmb = run_fmb(obj, model, cfg, **kw)
    return dict(amb_wall=float(h_amb.wall_time[-1]),
                fmb_wall=float(h_fmb.wall_time[-1]),
                wall_ratio=float(h_fmb.wall_time[-1] / h_amb.wall_time[-1]),
                final_amb=float(h_amb.eval_loss[-1]),
                final_fmb=float(h_fmb.eval_loss[-1]),
                paper_claim="AMB far outperforms FMB in hub-and-spoke")


def fig5_consensus_rounds() -> dict:
    """Fig. 5: effect of imperfect consensus (r=5 vs r=inf).

    Paper: vs epochs, r=5 ~ r=inf; vs wall time AMB >> FMB; AMB reaches
    1e-3 in <= half FMB's time (2.24x)."""
    d = 256
    obj = LinearRegression(dim=d)
    w_star = jax.random.normal(jax.random.PRNGKey(4), (d,))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=600)
    n, b_global = 20, 2000
    t_budget = amb_budget_from_fmb(model, n, b_global)
    base = EngineConfig(
        n=n, b_max=400, chunk=100, compute_time=t_budget,
        comm_time=0.3 * t_budget, fmb_batch_per_node=100, graph="ring",
        beta=BetaSchedule(k=1.0, mu=float(b_global)))
    out = {}
    kw = dict(epochs=100, key=jax.random.PRNGKey(0), sample_args=(w_star,),
              eval_fn=eval_fn, f_star=0.5 * obj.noise_var)
    for label, mode, r in [("r5", "gossip", 5), ("rinf", "exact", 0)]:
        cfg = dataclasses.replace(base, consensus_mode=mode,
                                  consensus_rounds=r or 5)
        h = run_amb(obj, model, cfg, **kw)
        out[f"amb_{label}_final"] = float(h.eval_loss[-1])
        out[f"amb_{label}_eps"] = float(h.consensus_eps.mean())
    h_fmb = run_fmb(obj, model, dataclasses.replace(
        base, consensus_mode="gossip"), **kw)
    out["fmb_final"] = float(h_fmb.eval_loss[-1])
    out["epoch_equivalence"] = out["amb_r5_final"] / out["amb_rinf_final"]
    out["paper_claim"] = "r=5 ~= perfect consensus per-epoch (Fig 5a)"
    return out


def fig7_induced_stragglers_ec2() -> dict:
    """Fig. 6+7: induced background-job stragglers on EC2 (3 bad / 2 mid /
    5 fast).  Paper: AMB ~2x faster (vs 1.5x with natural stragglers)."""
    obj = LogisticRegression(dim=64, num_classes=10)
    means = obj.make_class_means(jax.random.PRNGKey(6))
    eval_batch = obj.sample(jax.random.PRNGKey(13), (2048,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)
    f_star = float(eval_fn(_train_logreg_opt(obj, means)))
    model = InducedGroups(group_sizes=(5, 2, 3), zetas=(9.0, 18.0, 27.0),
                          lams=(1.0, 1.0, 1.0), b_ref=585)
    out = _speedup_run(obj, (means,), eval_fn, f_star, model,
                       n=10, b_global=5850, epochs=80, calibrate=True)
    out["paper_claim"] = "~2x faster with induced stragglers (Fig 7)"
    # histogram data (Fig 6): batch-size spread across groups
    from repro.core.stragglers import amb_budget_calibrated
    times = model.per_gradient_times(jax.random.PRNGKey(1), 10, 4 * 585)
    t_budget = amb_budget_calibrated(model, 10, 5850)
    b = np.asarray(amb_batch_sizes(times, t_budget))
    out["amb_batch_fast_over_bad"] = float(b[:5].mean() / b[7:].mean())
    return out


def fig9_hpc_pause_model() -> dict:
    """Fig. 8+9: HPC pause-model stragglers, 50 workers in 5 groups.

    Paper: AMB >= 5x faster (2.45s vs 12.7s to min cost)."""
    obj = LogisticRegression(dim=64, num_classes=10)
    means = obj.make_class_means(jax.random.PRNGKey(8))
    eval_batch = obj.sample(jax.random.PRNGKey(15), (2048,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)
    f_star = float(eval_fn(_train_logreg_opt(obj, means)))
    model = PauseModel(group_sizes=(10,) * 5, mus_ms=(5, 10, 20, 35, 55),
                       base_ms=1.5, b_ref=10)
    out = _speedup_run(obj, (means,), eval_fn, f_star, model,
                       n=50, b_global=500, epochs=80, graph="star",
                       rounds=1, calibrate=True)
    out["paper_claim"] = ">5x faster under HPC pause stragglers (Fig 9)"
    return out


def thm7_speedup_vs_n() -> dict:
    """Thm 7 + App. H: wall-clock speedup grows ~ sqrt(n-1) (bound) and
    ~ log(n)/(1+lam*zeta) for shifted exponentials."""
    lam, zeta = 2 / 3, 1.0
    out = {}
    for n in (5, 10, 25, 50, 100):
        model = ShiftedExponential(lam=lam, zeta=zeta, b_ref=60)
        b_global = 60 * n
        t_budget = amb_budget_from_fmb(model, n, b_global)
        s_f = 0.0
        epochs = 400
        for s in range(epochs):
            times = model.per_gradient_times(jax.random.PRNGKey(s), n, 240)
            s_f += float(fmb_finish_times(times, 60).max())
        s_a = epochs * t_budget
        ratio = s_f / s_a
        out[f"n{n}_measured"] = round(ratio, 3)
        out[f"n{n}_thm7_bound"] = round(theorem7_ratio(
            model.mean_batch_time(), model.std_batch_time(), n), 3)
        out[f"n{n}_logn_asymptote"] = round(
            shifted_exp_asymptotic_ratio(lam, zeta, n), 3)
        assert ratio <= out[f"n{n}_thm7_bound"] * 1.02
    out["paper_claim"] = "S_F <= (1 + sigma/mu sqrt(n-1)) S_A; -> log(n) limit"
    return out


def regret_scaling() -> dict:
    """Cor. 3/5: regret O(sqrt(m)) — fitted growth exponent ~ 0.5.

    Needs a regime where the noise-driven convergence tail spans the whole
    horizon (small per-epoch batch, high gradient noise, compact W per the
    paper's assumptions), otherwise regret accrues in the first few epochs
    and plateaus (exponent -> 0, trivially within the bound but
    uninformative).  Iterated: d=512 unconstrained diverged (W must be
    bounded, as the paper assumes); noise_var=1e-3 converges in ~12 epochs.
    With noise_var=4, d=64, radius=2 sqrt(d): growth persists to ~epoch 600
    of 800 and the fitted exponent ~0.38 <= 0.5."""
    d = 64
    nv = 4.0
    obj = LinearRegression(dim=d, noise_var=nv)
    w_star = jax.random.normal(jax.random.PRNGKey(21), (d,))
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    cfg = EngineConfig(
        n=10, b_max=16, chunk=8, compute_time=amb_budget_from_fmb(
            model, 10, 60), comm_time=0.3, fmb_batch_per_node=6,
        graph="paper", consensus_rounds=5,
        beta=BetaSchedule(k=1.0, mu=60.0),
        radius=float(2 * np.sqrt(d)))
    h = run_amb(obj, model, cfg, epochs=800, key=jax.random.PRNGKey(0),
                sample_args=(w_star,),
                eval_fn=lambda w: obj.population_loss(w, w_star),
                f_star=0.5 * nv)
    m = np.cumsum(np.asarray(h.potential_samples))
    r = np.asarray(h.regret)
    # Fit the *growth phase*: once the iterate converges, per-epoch regret
    # increments vanish and R(m) plateaus (exponent -> 0, trivially
    # sublinear).  Cor. 3 bounds the growth, so fit up to where R reaches
    # 90% of its final value, skipping the first few noisy epochs.
    grow = int(np.searchsorted(r, 0.9 * r[-1]))
    grow = max(grow, 12)            # guard: keep >= a few fit points
    lo = max(3, grow // 10)
    expo = float(np.polyfit(np.log(m[lo:grow + 1]),
                            np.log(np.maximum(r[lo:grow + 1], 1e-9)), 1)[0])
    # the whole-run exponent is reported too: plateau => far below 0.5
    expo_full = float(np.polyfit(np.log(m[lo:]),
                                 np.log(np.maximum(r[lo:], 1e-9)), 1)[0])
    return dict(regret_growth_exponent=round(expo, 3),
                regret_exponent_full_run=round(expo_full, 3),
                sqrt_m_ratio_final=float(r[-1] / np.sqrt(m[-1])),
                total_regret=float(r[-1]), total_samples=float(m[-1]),
                paper_claim="R(tau) = O(sqrt(m)) (Cor. 3)")


ALL = {
    "fig1a_linreg_ec2": fig1a_linreg_ec2,
    "fig1b_logreg_ec2": fig1b_logreg_ec2,
    "fig3_hub_and_spoke": fig3_hub_and_spoke,
    "fig5_consensus_rounds": fig5_consensus_rounds,
    "fig7_induced_stragglers": fig7_induced_stragglers_ec2,
    "fig9_hpc_pause_model": fig9_hpc_pause_model,
    "thm7_speedup_vs_n": thm7_speedup_vs_n,
    "regret_scaling": regret_scaling,
}
