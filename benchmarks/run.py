"""Benchmark entrypoint: one benchmark per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows; full result dicts go to
``artifacts/bench/<name>.json``.  ``--only <name>`` runs a subset.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    from . import extensions, paper_figs
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    benches = dict(paper_figs.ALL)
    benches.update(extensions.ALL)
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        res = fn()
        dt = time.time() - t0
        (outdir / f"{name}.json").write_text(json.dumps(res, indent=2))
        derived = res.get("speedup") or res.get("wall_ratio") or \
            res.get("regret_growth_exponent") or \
            res.get("epoch_equivalence") or res.get("n10_measured") or \
            res.get("eps_reduction_q8") or res.get("batch_recovery") or \
            res.get("midrun_loss_ratio") or 0.0
        print(f"{name},{dt * 1e6:.0f},{derived}", flush=True)

    if not args.skip_roofline and not args.only:
        from .roofline import summarize
        table = summarize()
        (outdir / "roofline.json").write_text(json.dumps(table, indent=2))
        for rec in table.get("rows", []):
            print(f"roofline/{rec['arch']}/{rec['shape']},0,"
                  f"{rec['dominant_term']}", flush=True)


if __name__ == "__main__":
    main()
