"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage:
    PYTHONPATH=src python -m benchmarks.report            # print tables
    PYTHONPATH=src python -m benchmarks.report --pick     # hillclimb picks
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

DRYRUN_DIR = Path("artifacts/dryrun")
BASELINE_DIR = Path("artifacts/dryrun_baseline")   # pre-§Perf snapshot

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "qwen3-8b", "qwen3-moe-30b-a3b", "command-r-plus-104b", "internlm2-20b",
    "zamba2-1.2b", "whisper-base", "rwkv6-3b", "phi3.5-moe-42b-a6.6b",
    "qwen2-1.5b", "internvl2-76b"]


def load(mesh=None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / "*.json"))):
        d = json.loads(Path(f).read_text())
        if mesh is None or d.get("mesh") == mesh:
            recs.append(d)
    key = lambda d: (ARCH_ORDER.index(d["arch"]) if d["arch"] in ARCH_ORDER
                     else 99, SHAPE_ORDER.index(d["shape"]), d["mesh"])
    return sorted(recs, key=key)


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f} GiB"


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | compile | HLO GFLOP/chip | HLO GiB/chip "
            "| coll GiB/chip | temp GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for d in load():
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d.get('compile_s', '?')}s "
            f"| {d['hlo_flops'] / 1e9:.1f} "
            f"| {d['hlo_bytes'] / 2**30:.2f} "
            f"| {d['collectives']['traffic_bytes'] / 2**30:.3f} "
            f"| {fmt_bytes(d.get('temp_size_in_bytes'))} |")
    return "\n".join(rows)


def roofline_table(mesh="16x16") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| useful_flops |",
            "|---|---|---|---|---|---|---|"]
    for d in load(mesh):
        rows.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {d['compute_s_roofline']:.4g} "
            f"| {d['memory_s_roofline']:.4g} "
            f"| {d['collective_s_roofline']:.4g} "
            f"| **{d['dominant_term']}** "
            f"| {d['useful_flops_frac']:.3f} |")
    return "\n".join(rows)


def hillclimb_picks(mesh="16x16") -> dict:
    """The three §Perf picks: worst roofline fraction (useful flops),
    most collective-bound, most paper-representative (AMB train step with
    the largest consensus-to-compute ratio)."""
    recs = load(mesh)
    worst_frac = min(
        (d for d in recs if d["useful_flops_frac"] > 0),
        key=lambda d: d["useful_flops_frac"])
    coll = max(recs, key=lambda d: (
        d["collective_s_roofline"] /
        max(d["compute_s_roofline"], d["memory_s_roofline"], 1e-12)))
    train = [d for d in recs if d["shape"] == "train_4k"]
    rep = max(train, key=lambda d: d["collective_s_roofline"])
    return {"worst_useful_flops": worst_frac, "most_collective_bound": coll,
            "paper_representative": rep}


def before_after_table(mesh="16x16") -> str:
    """Baseline (paper-faithful pre-optimization snapshot) vs optimized
    roofline terms, per (arch x shape); the §Perf summary table."""
    base = {}
    for f in sorted(glob.glob(str(BASELINE_DIR / "*.json"))):
        d = json.loads(Path(f).read_text())
        if d.get("mesh") == mesh:
            base[(d["arch"], d["shape"])] = d
    rows = ["| arch | shape | dominant (base -> opt) | binding term s "
            "(base -> opt) | speedup |",
            "|---|---|---|---|---|"]
    for d in load(mesh):
        b = base.get((d["arch"], d["shape"]))
        if b is None:
            continue
        bind = lambda r: max(r["compute_s_roofline"], r["memory_s_roofline"],
                             r["collective_s_roofline"])
        s_b, s_o = bind(b), bind(d)
        rows.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {b['dominant_term']} -> {d['dominant_term']} "
            f"| {s_b:.4g} -> {s_o:.4g} "
            f"| **{s_b / max(s_o, 1e-12):.2f}x** |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pick", action="store_true")
    ap.add_argument("--before-after", action="store_true")
    args = ap.parse_args()
    if args.before_after:
        print(before_after_table())
        return
    if args.pick:
        for k, d in hillclimb_picks().items():
            print(f"{k}: {d['arch']} x {d['shape']} "
                  f"(dom={d['dominant_term']}, "
                  f"useful={d['useful_flops_frac']:.3f}, "
                  f"coll_s={d['collective_s_roofline']:.4g})")
        return
    print("### Dry-run matrix\n")
    print(dryrun_table())
    print("\n### Roofline (single pod, 16x16)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
