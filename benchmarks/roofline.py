"""Roofline reporting: reads the dry-run artifacts and builds the §Roofline
table (compute / memory / collective terms, dominant bottleneck, useful-flops
ratio) per (arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
from pathlib import Path

DRYRUN_DIR = Path("artifacts/dryrun")


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / "*.json"))):
        d = json.loads(Path(f).read_text())
        if d.get("mesh") == mesh:
            recs.append(d)
    return recs


def summarize(mesh: str = "16x16") -> dict:
    rows = []
    for d in load_records(mesh):
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": round(d["compute_s_roofline"], 6),
            "memory_s": round(d["memory_s_roofline"], 6),
            "collective_s": round(d["collective_s_roofline"], 6),
            "dominant_term": d["dominant_term"],
            "model_flops": d["model_flops"],
            "hlo_flops_per_chip": d["hlo_flops"],
            "useful_flops_frac": round(d["useful_flops_frac"], 4),
            "bytes_per_device": d.get("temp_size_in_bytes"),
        })
    return {"mesh": mesh, "rows": rows}


def print_table(mesh: str = "16x16") -> None:
    t = summarize(mesh)
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>11s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in t["rows"]:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['dominant_term']:>11s} {r['useful_flops_frac']:7.3f}")


if __name__ == "__main__":
    print_table()
