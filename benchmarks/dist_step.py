"""Benchmark the repro.dist train steps: exact-psum vs gossip consensus.

Times, on a host-device mesh (forced device count, CPU-friendly smoke
config):

  * exact-consensus ``make_train_step`` (dual averaging),
  * ``make_gossip_train_step`` at several round counts r,
  * the ``gossip_combine`` K-way weighted combine: Pallas kernel
    (interpret mode on CPU) vs the pure-jnp reference, at model-sized
    message widths.

Writes ``artifacts/bench/BENCH_dist.json`` and prints the
``name,us_per_call,derived`` CSV rows (benchmarks/run.py conventions).

    PYTHONPATH=src python -m benchmarks.dist_step --steps 10
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import smoke_config                      # noqa: E402
from repro.core.dual_averaging import BetaSchedule          # noqa: E402
from repro.data import LMTokenStream, shard_batch           # noqa: E402
from repro.dist import use_sharding                         # noqa: E402
from repro.dist.amb import (AMBConfig, make_gossip_train_step,  # noqa: E402
                            make_train_step, num_workers)
from repro.dist.params import tree_shardings                # noqa: E402
from repro.kernels import ref                               # noqa: E402
from repro.kernels.gossip_combine import gossip_combine_pallas  # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.optim import make_optimizer                      # noqa: E402


def _time_it(fn, *args, iters: int = 5) -> float:
    """Median-free simple timing: best of ``iters`` after one warmup."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_train_steps(arch: str, steps: int, seq_len: int) -> dict:
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = smoke_config(arch)
    n = num_workers(mesh)
    beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           seed=0)
    b = jnp.array([2, 1, 2, 2], jnp.int32)
    out: dict = {"arch": arch, "mesh": "4x2", "workers": n,
                 "seq_len": seq_len, "steps_timed": steps}

    with use_sharding(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, mesh))
        batch = shard_batch(stream.batch(0, 0, 2 * n), mesh)

        opt = make_optimizer("dual_averaging", beta=beta)
        step = jax.jit(make_train_step(cfg, opt, mesh, AMBConfig()))
        st = opt.init(params)
        t = _time_it(lambda: step(params, st, batch, b), iters=steps)
        out["exact_step_s"] = t

        for r in (4, 16, 60):
            amb = AMBConfig(consensus="gossip", gossip_rounds=r, beta=beta)
            init_state, gstep = make_gossip_train_step(cfg, mesh, amb)
            gs = init_state(params)
            gstep_j = jax.jit(gstep)
            out[f"gossip_r{r}_step_s"] = _time_it(
                lambda: gstep_j(gs, batch, b), iters=steps)

    out["gossip_r4_overhead"] = out["gossip_r4_step_s"] / out["exact_step_s"]
    return out


def bench_gossip_combine(widths=(1 << 16, 1 << 20)) -> dict:
    """K-way weighted combine: Pallas (interpret on CPU) vs jnp reference."""
    out: dict = {"k": 3}
    for nmsg in widths:
        key = jax.random.PRNGKey(0)
        msgs = jax.random.normal(key, (3, nmsg), jnp.float32)
        w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
        ref_j = jax.jit(ref.gossip_combine_ref)
        t_ref = _time_it(ref_j, msgs, w)
        t_pal = _time_it(
            lambda: gossip_combine_pallas(msgs, w, interpret=True))
        got = gossip_combine_pallas(msgs, w, interpret=True)
        want = ref_j(msgs, w)
        err = float(jnp.max(jnp.abs(got - want)))
        out[f"n{nmsg}"] = {"jnp_ref_s": t_ref, "pallas_interpret_s": t_pal,
                           "max_abs_err": err,
                           "note": "interpret mode on CPU; compiled Pallas "
                                   "timing requires TPU"}
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args(argv)

    rec = {
        "name": "dist_step",
        "devices": len(jax.devices()),
        "train_steps": bench_train_steps(args.arch, args.steps,
                                         args.seq_len),
        "gossip_combine": bench_gossip_combine(),
    }
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "BENCH_dist.json").write_text(json.dumps(rec, indent=2))

    ts = rec["train_steps"]
    print("name,us_per_call,derived")
    print(f"dist_exact_step,{ts['exact_step_s'] * 1e6:.0f},1.0")
    for r in (4, 16, 60):
        print(f"dist_gossip_r{r}_step,{ts[f'gossip_r{r}_step_s'] * 1e6:.0f},"
              f"{ts[f'gossip_r{r}_step_s'] / ts['exact_step_s']:.2f}")
    print(f"[ok] wrote {outdir / 'BENCH_dist.json'}")
    return rec


if __name__ == "__main__":
    main()
