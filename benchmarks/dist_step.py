"""Benchmark the repro.dist train steps: exact-psum vs gossip consensus.

All steps are built through the Session API's
:func:`repro.api.protocol.build_protocol` — the same uniform
TrainState/epoch-driver surface the launchers use.  Times, on a
host-device mesh (forced device count, CPU-friendly smoke config):

  * the exact-consensus protocol step (dual averaging),
  * the gossip protocol step at several round counts r,
  * the ``gossip_combine`` K-way weighted combine through the
    :mod:`repro.kernels.router` hot path (compiled Pallas on TPU/GPU,
    jnp reference on CPU) vs the interpret-mode oracle, at model-sized
    message widths,
  * the ``dist_dataplane`` section: (a) steps/s of the synchronous
    build-put-step loop vs the prefetched data plane
    (:class:`repro.data.Prefetcher`) at several host-batch costs
    (0/0.5/1/2x the measured step time, modeled by
    :class:`repro.data.CostedSource`); (b) TrainState donation
    accounting — live-buffer counts stay flat across steps and the
    pre-step state's buffers are actually freed, for all four epoch
    drivers; (c) the kernel routing decision and its delta vs the
    interpret oracle,
  * the ``dist_pipelined`` section: (a) the staleness-1 pipelined step vs
    the sequential gossip protocol — "sequential" meaning the paper's two
    distinct windows, a compute-phase dispatch followed by a
    consensus-phase dispatch, which is exactly the structure pipelining
    absorbs (the fused one-program sequential step is reported too, for
    transparency; on CPU hosts the two phases share the same cores, so
    the measurable win is the eliminated message materialization +
    dispatch, while on TPU the ICI rounds hide under the backward pass);
    (b) the 2x16x16 dry-run mesh cost model — lower+compile FLOPs and
    cross-pod collective-permute bytes per gossip round for each
    consensus strategy vs the exact all-reduce step (subprocess with 512
    forced host devices; compile only, never executed),
  * the ``dist_async`` section: simulated epoch wall time vs staleness D
    for the AMB-DG async driver against the sequential and pipelined
    schedules, under the paper's straggler clock with a long consensus
    window (T_c > T) — the regime bounded staleness reclaims,
  * the ``dist_controller`` section: the online self-tuning controller
    (``--controller``; :mod:`repro.control`) vs static (D, budget)
    settings under a *shifting* straggler clock — the per-gradient rate
    jumps 3x mid-run, the statics keep their launch tuning, the
    controller re-solves Lemma 6 and retunes D from telemetry,
  * the ``dist_churn`` section: graceful degradation under Poisson
    worker churn (:mod:`repro.faults`) — loss trajectory and epoch wall
    for coded (``--redundancy``; :mod:`repro.dist.redundancy`) vs
    uncoded fleets against the no-churn baselines, plus the
    survivor-relayout fast-path check (churned ring combines compile to
    collective-permutes, never the dense ``P @ m`` fallback) and the
    relayout-vs-dense combine timing,
  * the ``dist_serve`` section: continuous batching
    (:mod:`repro.serve`) vs static rebatching on one staggered-arrival
    workload, with background AMB fine-tune epochs absorbed into the
    round budget — per-op costs are *measured* on the live engine, then
    both lanes replay deterministically on a
    :class:`repro.serve.SyntheticClock` so the comparison isolates the
    scheduling policy; reports TTFT/TPOT p50/p99, tokens/s, and the
    fine-tune loss trajectory in one run.

Writes ``artifacts/bench/BENCH_dist.json`` and prints the
``name,us_per_call,derived`` CSV rows (benchmarks/run.py conventions).

    PYTHONPATH=src python -m benchmarks.dist_step --steps 10
"""
from __future__ import annotations

import os
import subprocess
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.api.protocol import build_protocol               # noqa: E402
from repro.configs import smoke_config                      # noqa: E402
from repro.core.dual_averaging import BetaSchedule          # noqa: E402
from repro.data import LMTokenStream, put_batch             # noqa: E402
from repro.dist import use_sharding                         # noqa: E402
from repro.dist.amb import AMBConfig, num_workers           # noqa: E402
from repro.dist.params import tree_shardings                # noqa: E402
from repro.kernels import ref                               # noqa: E402
from repro.kernels.gossip_combine import gossip_combine_pallas  # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.optim import make_optimizer                      # noqa: E402


def _time_it(fn, *args, iters: int = 5) -> float:
    """Median-free simple timing: best of ``iters`` after one warmup."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_train_steps(arch: str, steps: int, seq_len: int) -> dict:
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = smoke_config(arch)
    n = num_workers(mesh)
    beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           seed=0)
    b = jnp.array([2, 1, 2, 2], jnp.int32)
    out: dict = {"arch": arch, "mesh": "4x2", "workers": n,
                 "seq_len": seq_len, "steps_timed": steps}

    with use_sharding(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, mesh))
        batch = put_batch(stream.batch(0, 0, 2 * n), mesh)

        opt = make_optimizer("dual_averaging", beta=beta)
        proto = build_protocol(cfg, mesh, AMBConfig(), optimizer=opt)
        step = jax.jit(proto.step)
        st = proto.init(params)
        t = _time_it(lambda: step(st, batch, b), iters=steps)
        out["exact_step_s"] = t

        for r in (4, 16, 60):
            amb = AMBConfig(consensus="gossip", gossip_rounds=r, beta=beta)
            gproto = build_protocol(cfg, mesh, amb)
            gs = gproto.init(params)
            gstep_j = jax.jit(gproto.step)
            out[f"gossip_r{r}_step_s"] = _time_it(
                lambda: gstep_j(gs, batch, b), iters=steps)

    out["gossip_r4_overhead"] = out["gossip_r4_step_s"] / out["exact_step_s"]
    return out


def bench_gossip_combine(widths=(1 << 16, 1 << 20)) -> dict:
    """K-way weighted combine: the routed hot path vs the interpret oracle.

    ``routed_s`` is the headline — what :func:`repro.kernels.ops.
    gossip_combine` actually executes after :mod:`repro.kernels.router`
    picks an implementation (compiled Pallas on TPU/GPU, the compiled
    jnp reference on CPU).  The interpret-mode Pallas timing is kept as
    a diagnostic only: it emulates the TPU grid step by step and must
    never be a production path.
    """
    from repro.kernels import ops as kops
    from repro.kernels import router
    routed = router.resolve()
    out: dict = {"k": 3, "backend": jax.default_backend(),
                 "routed_impl": routed,
                 "note": "routed_s = the ops.gossip_combine hot path "
                         "(router decision above); pallas_interpret_s "
                         "is the grid-emulation oracle, diagnostic only"}
    for nmsg in widths:
        key = jax.random.PRNGKey(0)
        msgs = jax.random.normal(key, (3, nmsg), jnp.float32)
        w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
        routed_j = jax.jit(kops.gossip_combine)
        t_routed = _time_it(routed_j, msgs, w)
        ref_j = jax.jit(ref.gossip_combine_ref)
        t_ref = _time_it(ref_j, msgs, w)
        t_pal = _time_it(
            lambda: gossip_combine_pallas(msgs, w, interpret=True))
        got = gossip_combine_pallas(msgs, w, interpret=True)
        want = routed_j(msgs, w)
        err = float(jnp.max(jnp.abs(got - want)))
        out[f"n{nmsg}"] = {"routed_s": t_routed, "jnp_ref_s": t_ref,
                           "pallas_interpret_s": t_pal,
                           "interpret_slowdown_vs_routed": t_pal / t_routed,
                           "max_abs_err": err}
    return out


def bench_dataplane(arch: str, steps: int, seq_len: int,
                    cost_factors=(0.0, 0.5, 1.0, 2.0)) -> dict:
    """The step-time critical path: prefetch overlap, donation, routing.

    (a) **Prefetch overlap** — steps/s of the synchronous loop (build
    the host batch, ``put_batch``, then step — the pre-dataplane
    behavior, ``session.run(prefetch=0)``) vs the prefetched data plane
    (``prefetch=2``: a background thread double-buffers host build +
    device put ahead of the consumer), at host-batch costs of
    0/0.5/1/2x the measured bare step time.  The cost is modeled by
    :class:`repro.data.CostedSource` as a GIL-releasing sleep (an
    I/O-bound input path), so the overlap measured here is the overlap
    the thread actually achieves.  At cost ~ step time the sync loop
    pays build + step serially while the prefetched loop hides the
    build entirely — the acceptance regime.

    (b) **Donation accounting** — for each of the four epoch drivers:
    step twice, then check the process-wide live-buffer count stays
    flat across further steps and every leaf of the pre-step TrainState
    was actually freed (``donate_argnums=0`` aliasing in effect — the
    old iterate's buffers are reused, not shadowed).

    (c) **Kernel routing** — the router's decision for this backend
    (the hot path never runs interpret-mode Pallas on CPU).
    """
    from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
    from repro.data import CostedSource
    from repro.kernels import router

    train = TrainSpec(arch=arch, smoke=True, seq_len=seq_len,
                      batch_per_worker=2, data=4, model=2)
    out: dict = {"arch": arch, "mesh": "4x2", "seq_len": seq_len,
                 "steps_timed": steps, "prefetch_depth": 2}

    session = AMBSession(train, ClockSpec(kind="simulated"),
                         ConsensusSpec())
    source = session.batch_source()
    session.run(2, source)                     # compile + warm the plane
    t0 = time.perf_counter()
    session.run(steps, source, prefetch=0)
    bare_step_s = (time.perf_counter() - t0) / steps
    out["bare_step_s"] = bare_step_s

    sweep = {}
    for f in cost_factors:
        costed = CostedSource(source, f * bare_step_s)
        t0 = time.perf_counter()
        session.run(steps, costed, prefetch=0)
        t_sync = (time.perf_counter() - t0) / steps
        t0 = time.perf_counter()
        session.run(steps, costed, prefetch=2)
        t_pre = (time.perf_counter() - t0) / steps
        sweep[f"cost_{f:g}x"] = {
            "host_batch_cost_s": f * bare_step_s,
            "sync_steps_per_s": 1.0 / t_sync,
            "prefetched_steps_per_s": 1.0 / t_pre,
            "speedup": t_sync / t_pre,
        }
    out["overlap"] = sweep

    donation = {}
    for label, kw in (("exact", {}),
                      ("gossip", dict(consensus="gossip", graph="ring")),
                      ("pipelined", dict(consensus="gossip", graph="ring",
                                         pipeline=True)),
                      ("async_D2", dict(consensus="gossip", graph="ring",
                                        async_epochs=True, staleness=2))):
        s = AMBSession(train, ClockSpec(kind="simulated"),
                       ConsensusSpec(**kw))
        src = s.batch_source()
        s.run(2, src)                          # compile outside the count
        live_before = len(jax.live_arrays())
        old = s.state
        s.run(2, src)
        live_after = len(jax.live_arrays())
        freed = all(leaf.is_deleted()
                    for leaf in jax.tree.leaves(old))
        donation[label] = {
            "live_arrays_before": live_before,
            "live_arrays_after": live_after,
            "live_arrays_flat": bool(live_after <= live_before),
            "old_state_freed": bool(freed),
        }
        del old, s, src
    out["donation"] = donation

    out["kernel_routing"] = {
        "backend": jax.default_backend(),
        "mode": router.mode(),
        "resolved": router.resolve(),
        "interpret_on_hot_path": bool(router.resolve()
                                      == "pallas_interpret"),
    }
    return out


def bench_pipelined(arch: str, steps: int, seq_len: int,
                    rounds=(16, 60)) -> dict:
    """Pipelined step vs the sequential (two-window) gossip protocol.

    The sequential baseline runs the paper's epoch as its two distinct
    windows — a compute-phase program (masked grads -> packed message)
    then a consensus-phase program (gossip -> dual update) — which is how
    an unpipelined system executes T followed by T_c.  The pipelined step
    runs the same consensus *inside* the compute program, against the
    previous epoch's message (staleness 1).
    """
    from repro.dist.amb import (_local_grads, pack_messages,
                                seq_weights_from_b, strategy_from_config,
                                unpack_duals)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = smoke_config(arch)
    n = num_workers(mesh)
    per = 2
    beta = BetaSchedule(k=20.0, mu=1.0, scale=50.0)
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=seq_len,
                           seed=0)
    b = jnp.array([2, 1, 2, 2], jnp.int32)
    out: dict = {"arch": arch, "mesh": "4x2", "workers": n,
                 "seq_len": seq_len,
                 "note": "sequential = compute-phase dispatch + "
                         "consensus-phase dispatch (the protocol's two "
                         "windows); fused = one-program sequential step"}

    with use_sharding(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, mesh))
        batch = put_batch(stream.batch(0, 0, per * n), mesh)
        for r in rounds:
            amb = AMBConfig(consensus="gossip", gossip_rounds=r, beta=beta)
            strategy = strategy_from_config(amb, mesh)
            gproto = build_protocol(cfg, mesh, amb)
            gs = gproto.init(params)

            def compute_phase(state, batch, b):
                beta_t = amb.beta(state["t"].astype(jnp.float32) + 1.0)
                sw = seq_weights_from_b(b, n * per, n).reshape(n, per)
                grads, _ = _local_grads(cfg, state, batch, sw, beta_t,
                                        None, n, per)
                bw = jnp.minimum(b, per).astype(jnp.float32)
                return pack_messages(state["z"], grads, n * bw, n)

            def consensus_phase(state, msg):
                return unpack_duals(strategy.combine(msg), state["z"], n)

            cp, sp = jax.jit(compute_phase), jax.jit(consensus_phase)
            msg = cp(gs, batch, b)
            jax.block_until_ready(msg)

            def split_epoch():
                return sp(gs, cp(gs, batch, b))

            t_split = _time_it(split_epoch, iters=steps)
            gj = jax.jit(gproto.step)
            t_fused = _time_it(lambda: gj(gs, batch, b), iters=steps)

            pproto = build_protocol(cfg, mesh, amb, pipeline=True)
            pj = jax.jit(pproto.step)
            ps, _ = pj(pproto.init(params), batch, b)  # warm: in flight
            t_pipe = _time_it(lambda: pj(ps, batch, b), iters=steps)

            out[f"r{r}"] = {
                "sequential_step_s": t_split,
                "sequential_fused_step_s": t_fused,
                "pipelined_step_s": t_pipe,
                "overlap_ratio": t_pipe / t_split,
                "overlap_demonstrated": bool(t_pipe < t_split),
            }
    return out


def bench_async(arch: str, steps: int, seq_len: int,
                stalenesses=(1, 2, 4), comm_time: float = 8.0) -> dict:
    """Epoch wall time vs staleness under the paper's straggler clock.

    Drives an :class:`repro.api.AMBSession` per epoch driver — the
    sequential gossip protocol (two windows: T then T_c), the staleness-1
    pipeline, and the AMB-DG async driver at several staleness values D —
    all under the simulated straggler clock with a deliberately *long*
    consensus window (T_c > T, the regime the paper's fixed windows
    handle worst).  The simulated per-epoch wall time follows the
    protocol schedule: ``T + T_c`` sequential, ``max(T, T_c)`` pipelined,
    ``max(T, T_c / D)`` async — bounded staleness lets one consensus
    spread over D compute windows, so the epoch rate returns to
    compute-bound once ``D >= T_c / T``.  The host-measured step time and
    final loss are reported alongside (same gossip operator and rounds
    everywhere; only the schedule differs).
    """
    from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec

    if steps < 1:
        raise ValueError("bench_async needs --steps >= 1")
    train = TrainSpec(arch=arch, smoke=True, seq_len=seq_len,
                      batch_per_worker=2, data=4, model=2)
    clock = ClockSpec(kind="simulated", comm_time=comm_time)
    out: dict = {"arch": arch, "mesh": "4x2", "seq_len": seq_len,
                 "steps": steps, "comm_time_s": comm_time,
                 "note": "sim_epoch_wall_s: sequential T+T_c, pipelined "
                         "max(T,T_c), async max(T,T_c/D); straggler "
                         "clock draws identical across drivers"}

    def drive(label: str, **spec_kw):
        session = AMBSession(train, clock, ConsensusSpec(
            consensus="gossip", gossip_rounds=4, **spec_kw))
        stream = LMTokenStream(vocab_size=session.cfg.vocab_size,
                               seq_len=seq_len, seed=0)
        best = float("inf")
        for i in range(steps):
            m = session.step(stream.batch(0, i, session.global_batch))
            if i > 0 or steps == 1:        # skip the compile step when
                best = min(best, m["step_s"])   # there is a later one
        session.flush()
        out[label] = {"sim_epoch_wall_s": session.sim_wall / steps,
                      "budget_T_s": m["budget_s"],
                      "host_step_s": best,
                      "final_loss": m["loss"]}

    drive("sequential")
    drive("pipelined", pipeline=True)
    for d in stalenesses:
        drive(f"async_D{d}", async_epochs=True, staleness=d)
    dmax = max(stalenesses)
    out["wall_speedup_async_vs_sequential"] = (
        out["sequential"]["sim_epoch_wall_s"]
        / out[f"async_D{dmax}"]["sim_epoch_wall_s"])
    return out


def bench_controller(arch: str, steps: int, seq_len: int,
                     comm_time: float = 4.0,
                     static_ds=(1, 2, 4)) -> dict:
    """Self-tuning controller vs static (D, budget) under a shifting clock.

    The scenario static tuning cannot win: the cluster's per-gradient
    rate *changes mid-run* (epoch ``switch``: every worker gets ~3x
    faster — a contention burst ending, a thermal cap lifting).  Every
    run uses the async driver and the same deliberately long consensus
    window T_c; the static baselines keep the budget T0 (the Lemma-6
    solve for the *initial* rate) and a fixed staleness D for the whole
    run, while the controller starts from exactly (T0, D=1) and retunes
    from telemetry: after the shift it cuts T toward the new Lemma-6
    solve (rate-limited, so over a few decisions) and raises D as the
    measured ``T_c / T`` ratio climbs — keeping epochs compute-bound at
    the *new* rate.  Per-epoch simulated wall is ``max(T, T_c / D)``
    (see :func:`bench_async`), so a static run pays ``T0`` forever while
    the controller converges to ``~max(T_new, T_c / D_new)``.

    Reports total simulated wall and final loss per config, plus the
    two acceptance booleans: controller wall <= best static wall, and
    controller loss no worse (5% tolerance) than that best-wall static
    run's.
    """
    from repro.api import (AMBSession, ClockSpec, ConsensusSpec,
                           ControllerSpec, TrainSpec)
    from repro.api.clock import SimulatedClock
    from repro.core.stragglers import ShiftedExponential

    epochs = max(3 * steps, 12)
    switch = epochs // 3            # shift early: 2/3 of the run is "after"
    train = TrainSpec(arch=arch, smoke=True, seq_len=seq_len,
                      batch_per_worker=2, data=4, model=2)
    n, bpw = 4, train.batch_per_worker
    slow = ShiftedExponential(lam=2.0 / 3.0, zeta=1.0, b_ref=bpw)
    fast = ShiftedExponential(lam=2.0, zeta=1.0 / 3.0, b_ref=bpw)  # 3x
    t0_budget = (1.0 + n / (n * bpw)) * slow.mean_batch_time()  # Lemma 6

    class _ShiftingClock(SimulatedClock):
        """Simulated clock whose straggler model swaps mid-run."""

        def __init__(self):
            SimulatedClock.__init__(self, slow, n, bpw,
                                    compute_time=t0_budget)
            self._epoch = 0

        def epoch(self, key):
            self.model = slow if self._epoch < switch else fast
            self._epoch += 1
            return (self.model.per_gradient_times(key, self.n, self.bpw),
                    self.budget_t)

    clock_spec = ClockSpec(kind="simulated", comm_time=comm_time,
                           compute_time=t0_budget)
    out: dict = {"arch": arch, "mesh": "4x2", "epochs": epochs,
                 "switch_epoch": switch, "comm_time_s": comm_time,
                 "budget_T0_s": t0_budget,
                 "note": "per-gradient rate shifts 3x faster at "
                         "switch_epoch; statics keep (T0, D) throughout, "
                         "controller retunes from telemetry"}

    def drive(label: str, staleness: int, controller: bool):
        ctl = ControllerSpec(enabled=True, interval=2, warmup=2) \
            if controller else None
        session = AMBSession(
            train, clock_spec,
            ConsensusSpec(consensus="gossip", gossip_rounds=4,
                          async_epochs=True, staleness=staleness),
            ctl)
        session.clock = _ShiftingClock()     # same draws for every config
        stream = LMTokenStream(vocab_size=session.cfg.vocab_size,
                               seq_len=seq_len, seed=0)
        decisions = []
        for i in range(epochs):
            m = session.step(stream.batch(0, i, session.global_batch))
            if "action" in m:
                decisions.append({"epoch": i, **{
                    k: m["action"][k] for k in ("budget", "staleness",
                                                "reason")
                    if m["action"][k] is not None}})
        session.flush()
        out[label] = {"sim_wall_total_s": session.sim_wall,
                      "sim_wall_per_epoch_s": session.sim_wall / epochs,
                      "final_budget_T_s": m["budget_s"],
                      "final_staleness": m["staleness"],
                      "final_loss": m["loss"]}
        if controller:
            out[label]["decisions"] = decisions

    for d in static_ds:
        drive(f"static_D{d}", staleness=d, controller=False)
    drive("controller", staleness=1, controller=True)

    best = min((f"static_D{d}" for d in static_ds),
               key=lambda k: out[k]["sim_wall_total_s"])
    out["best_static"] = best
    out["controller_beats_best_static_wall"] = bool(
        out["controller"]["sim_wall_total_s"]
        <= out[best]["sim_wall_total_s"] * 1.001)
    out["loss_no_worse"] = bool(
        out["controller"]["final_loss"]
        <= out[best]["final_loss"] * 1.05)
    return out


_MULTIPOD_VARIANTS = (("gossip", "torus"), ("gossip_q8", "torus"),
                      ("gossip_q4", "torus"), ("gossip", "ring"))


def multipod_probe(arch: str, seq_len: int) -> dict:
    """(subprocess body) 2x16x16 lower+compile cost model, JSON to stdout.

    Per consensus strategy: compiled cost-analysis FLOPs and the
    collective-permute footprint of one gossip round (the fori_loop body
    appears once in HLO, so the parsed permute bytes *are* per-round),
    vs the exact-consensus all-reduce step.  The analytic per-worker wire
    bytes from ``ConsensusStrategy.wire_bytes_per_round`` are reported
    alongside, and ``permute_bytes_by_dtype`` breaks the permutes down by
    element type — the quantized strategies' planes must show up as u8
    (the optimization barriers in ``QuantizedGossipConsensus`` pin the
    wire; the rounding draws are partitionable-threefry, i.e. shard-local,
    so no u32 RNG resharding rides the interconnect either).
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import InputShape
    from repro.core.dual_averaging import BetaSchedule as BS
    from repro.dist.amb import strategy_from_config
    from repro.launch import specs as S
    from repro.launch.dryrun import _costs
    from repro.launch.mesh import make_production_mesh
    from repro.optim import DualAveragingOpt

    mesh = make_production_mesh(multi_pod=True)
    cfg = smoke_config(arch)
    n = num_workers(mesh)
    beta = BS(k=20.0, mu=1.0, scale=50.0)
    params_sds = S.abstract_params(cfg)
    pspecs = tree_shardings(params_sds, mesh)
    as_in = lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh)
    zsh = NamedSharding(mesh, P(("pod", "data")))

    def protocol_state_in(proto, **spec_overrides):
        """Abstract TrainState inputs: structure from the protocol's own
        init (the single source of truth), shardings assigned per key."""
        state_sds = jax.eval_shape(proto.init, params_sds)
        specs = {"t": NamedSharding(mesh, P())}
        for key, sub in state_sds.items():
            if key == "t":
                continue
            specs[key] = spec_overrides.get(
                key, jax.tree.map(lambda s: zsh, sub))
        return jax.tree.map(as_in, state_sds, specs)

    shape = InputShape(name="probe", kind="train", global_batch=n,
                       seq_len=seq_len)
    batch_in = S.train_input_specs(cfg, shape, mesh)
    b_in = S.worker_batch_spec(mesh)
    d_msg = 1 + sum(int(np.prod(p.shape)) for p in
                    jax.tree.leaves(params_sds))

    out: dict = {"mesh": "2x16x16", "chips": 512, "workers": n,
                 "arch": arch, "seq_len": seq_len}
    import time as _t
    for consensus, graph in _MULTIPOD_VARIANTS:
        amb = AMBConfig(consensus=consensus, gossip_rounds=1, graph=graph,
                        beta=beta)
        with use_sharding(mesh):
            gproto = build_protocol(cfg, mesh, amb)
            state_in = protocol_state_in(gproto, w0=pspecs)
            t0 = _t.time()
            lowered = jax.jit(gproto.step).lower(state_in, batch_in, b_in)
            t1 = _t.time()
            c = _costs(lowered.compile())
            t2 = _t.time()
            strategy = strategy_from_config(amb, mesh)
        permute = c["collectives"]["collective-permute"]
        out[f"{consensus}_{graph}"] = {
            "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
            "hlo_flops": c["flops"],
            "permute_per_round": {"count": permute["count"],
                                  "bytes": permute["bytes"]},
            "permute_bytes_by_dtype": permute["by_dtype"],
            "all_reduce": c["collectives"]["all-reduce"],
            "wire_bytes_per_round_per_worker":
                strategy.wire_bytes_per_round(d_msg),
        }

    opt = DualAveragingOpt()
    with use_sharding(mesh):
        proto = build_protocol(cfg, mesh, AMBConfig(), optimizer=opt)
        opt_specs = tree_shardings(jax.eval_shape(opt.init, params_sds),
                                   mesh)
        exact_state_in = protocol_state_in(proto, params=pspecs,
                                           opt=opt_specs)
        t0 = _t.time()
        lowered = jax.jit(proto.step).lower(exact_state_in, batch_in, b_in)
        t1 = _t.time()
        c = _costs(lowered.compile())
        t2 = _t.time()
    out["exact_allreduce"] = {
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "hlo_flops": c["flops"],
        "permute": c["collectives"]["collective-permute"],
        "all_reduce": c["collectives"]["all-reduce"],
    }
    return out


def bench_churn(arch: str, steps: int, seq_len: int,
                leave_rate: float = 0.35, rejoin_rate: float = 0.5,
                redundancy: int = 2) -> dict:
    """Graceful degradation under Poisson churn: coded vs uncoded.

    Four runs on the 8-way host mesh sharing the same model seed, data
    stream, and straggler draws — {no churn, Poisson churn} x {uncoded,
    coded rho=2} — driven through ``session.run(faults=...)``, i.e. the
    same :class:`repro.faults.FaultInjector` path a launcher uses.  The
    interesting comparison is the *loss trajectory*: the uncoded fleet
    loses every downed worker's shard outright (smaller, noisier
    effective batch), while coded placement lets the surviving replica
    holders re-cover the block with decode weights that keep the
    gradient unbiased — so the coded churned trajectory should track
    the no-churn baseline and the uncoded churned one should trail it.

    Also reports (a) the survivor-relayout fast-path check — the
    compiled combine for a churned ring mask must contain
    collective-permutes and no dense dot, i.e. elastic membership never
    falls back to ``P @ m`` on circulant graphs — and (b) the measured
    combine time of the relayout taps vs the dense masked operator
    (``relayout=False``) on the same survivor mask.
    """
    from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
    from repro.dist import SurvivorTaps, make_strategy
    from repro.faults import FaultInjector, PoissonChurn
    from jax.sharding import NamedSharding, PartitionSpec as P

    epochs = max(steps, 12)
    clock = ClockSpec(kind="simulated")
    model = PoissonChurn(leave_rate=leave_rate, rejoin_rate=rejoin_rate,
                         seed=11)
    out: dict = {"arch": arch, "mesh": "8", "seq_len": seq_len,
                 "epochs": epochs, "leave_rate": leave_rate,
                 "rejoin_rate": rejoin_rate, "redundancy": redundancy,
                 "note": "same seed/stream/straggler draws across runs; "
                         "loss_tail = mean loss over the last half of "
                         "the trajectory"}

    def drive(label: str, rho: int, churn: bool):
        session = AMBSession(
            TrainSpec(arch=arch, smoke=True, seq_len=seq_len,
                      batch_per_worker=2, data=8, redundancy=rho),
            clock, ConsensusSpec(consensus="gossip", gossip_rounds=3))
        injector = FaultInjector(model) if churn else None
        losses: list = []
        session.run(epochs, prefetch=0, faults=injector,
                    on_step=lambda s, m: losses.append(float(m["loss"])))
        out[label] = {
            "losses": losses,
            "loss_tail": sum(losses[epochs // 2:]) / (epochs - epochs // 2),
            "sim_epoch_wall_s": session.sim_wall / epochs,
            "membership_changes": (0 if injector is None
                                   else injector.membership_changes)}
        session.close()

    drive("nochurn_uncoded", 1, churn=False)
    drive("nochurn_coded", redundancy, churn=False)
    drive("churn_uncoded", 1, churn=True)
    drive("churn_coded", redundancy, churn=True)

    # paired trajectory divergence: churned vs no-churn runs share the
    # seed, stream, and straggler draws, so the per-step loss delta is
    # the churn effect with batch-composition noise cancelled
    for coding in ("uncoded", "coded"):
        pairs = zip(out[f"churn_{coding}"]["losses"],
                    out[f"nochurn_{coding}"]["losses"])
        out[f"{coding}_trajectory_divergence"] = (
            sum(abs(a - b) for a, b in pairs) / epochs)
    out["coded_churn_excess"] = (out["churn_coded"]["loss_tail"]
                                 - out["nochurn_coded"]["loss_tail"])
    out["uncoded_churn_excess"] = (out["churn_uncoded"]["loss_tail"]
                                   - out["nochurn_uncoded"]["loss_tail"])

    # estimator fidelity over the same churn trajectory: the gradient
    # estimate is the weight-w_s average of per-sample gradients, so its
    # bias is exactly the deviation of the realized per-sample weights
    # from the ideal all-ones coverage.  Uncoded, a downed worker's
    # block samples get weight 0 (dropped data -> biased estimate);
    # coded, any surviving replica holder re-covers them at weight 1.
    from repro.dist import CodedAssignment, epoch_weights
    n, per = 8, 2
    asg = CodedAssignment(n, redundancy)
    shifts, nodes = asg.shifts(per), asg.data_nodes()
    cov = {"uncoded": [], "coded": []}
    bias = {"uncoded": [], "coded": []}
    for e in range(epochs):
        active = model.fleet(e, n).active.copy()
        if not active.any():
            active[0] = True
        b = jnp.asarray(np.where(active, per, 0), jnp.int32)
        for coding, a in (("uncoded", None), ("coded", asg)):
            sw = np.asarray(epoch_weights(b, n, per, a)[0])
            groups = asg.groups if a is not None else n
            block_w = np.zeros((groups, per))
            for i in range(n):
                g = int(nodes[i]) if a is not None else i
                s0 = int(shifts[i]) if a is not None else 0
                for s in range(per):
                    block_w[g, (s + s0) % per] += sw[i, s]
            cov[coding].append(float((block_w > 0).mean()))
            bias[coding].append(float(np.sqrt(((block_w - 1) ** 2).mean())))
    out["estimator_fidelity"] = {
        "note": "per-sample weight coverage/bias of the decoded "
                "gradient estimate under the churn masks (b_i = per "
                "for survivors); ideal = every sample weighted 1",
        "uncoded_coverage": sum(cov["uncoded"]) / epochs,
        "coded_coverage": sum(cov["coded"]) / epochs,
        "uncoded_weight_rmse": sum(bias["uncoded"]) / epochs,
        "coded_weight_rmse": sum(bias["coded"]) / epochs,
    }
    fid = out["estimator_fidelity"]
    out["coded_holds_estimate"] = bool(
        fid["coded_coverage"] >= fid["uncoded_coverage"]
        and fid["coded_weight_rmse"] <= fid["uncoded_weight_rmse"] + 1e-9)

    # fast-path check + relayout-vs-dense combine timing on one
    # representative churned mask (non-adjacent failures: the mask the
    # dense induced-subgraph operator cannot even express on a ring)
    mask = (True, True, False, True, True, False, True, True)
    mesh = jax.make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P("data"))
    msgs = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (8, 1 << 16)), sh)
    fast = make_strategy("gossip", 8, rounds=3, graph="ring", active=mask)
    assert isinstance(fast.taps, SurvivorTaps)
    txt = jax.jit(fast.combine, in_shardings=sh, out_shardings=sh).lower(
        jax.ShapeDtypeStruct((8, 1 << 16), jnp.float32)).compile().as_text()
    # the dense P @ m fallback compiles to an all-gather of the full
    # worker axis followed by a dot over it (no permutes); the tap fast
    # path compiles to per-tap collective-permutes with no all-gather —
    # its only dot contracts the K tap weights, not the worker axis
    out["survivor_fast_path"] = {
        "collective_permute_in_hlo": "collective-permute" in txt,
        "dense_gather_in_hlo": "all-gather" in txt,
        "taps_per_round": fast.taps.k,
        "relayout_combine_s": _time_it(
            jax.jit(fast.combine, in_shardings=sh, out_shardings=sh), msgs),
    }
    # the dense fallback needs a connected induced subgraph to exist
    dense = make_strategy("gossip", 8, rounds=3, graph="ring",
                          active=(True,) * 7 + (False,), relayout=False)
    out["survivor_fast_path"]["dense_fallback_combine_s"] = _time_it(
        jax.jit(dense.combine, in_shardings=sh, out_shardings=sh), msgs)
    return out


def bench_serve(arch: str, seq_len: int, n_requests: int = 12,
                slots: int = 4, cache_len: int = 64) -> dict:
    """Continuous batching vs static rebatching, fine-tune interleaved.

    One staggered workload (heterogeneous prompt lengths AND generation
    lengths) served twice: through the :class:`repro.serve.SlotEngine`
    + :class:`repro.serve.ServeScheduler` (continuous admission, slot
    reuse, background AMB fine-tune epochs absorbing idle round budget)
    and through :func:`repro.serve.serve_static` (groups of ``slots``
    barrier on their last arrival, pad to the group max, decode until
    the slowest member finishes).

    Timing protocol: prefill-per-token, decode-round, and train-epoch
    costs are *measured* on the live engine/session first, then both
    lanes replay on a :class:`repro.serve.SyntheticClock` configured
    with those costs — so jit compilation never pollutes TTFT, the
    lanes see identical op prices, and the reported deltas are purely
    the scheduling policy (the same reason the paper reports fixed-time
    epochs, not wall-clock luck).
    """
    import random as _random

    from repro.api import AMBSession, ClockSpec, ConsensusSpec, TrainSpec
    from repro.serve import (AdmissionPolicy, Request, RequestQueue,
                             ServeMetrics, ServeScheduler, SlotEngine,
                             SyntheticClock, serve_static)

    train = TrainSpec(arch=arch, smoke=True, seq_len=seq_len,
                      batch_per_worker=2, data=4, model=2,
                      optimizer="adamw")
    session = AMBSession(train, ClockSpec(kind="simulated"),
                         ConsensusSpec())
    cfg, mesh = session.cfg, session.mesh
    if cfg.family not in ("dense", "vlm"):
        session.close()
        return {"skipped": f"static baseline needs dense/vlm, got "
                           f"{cfg.family}"}

    # -- measure the op costs on the live engine/session ------------------
    probe = SlotEngine(session.params, cfg, slots=slots,
                       cache_len=cache_len, mesh=mesh)
    prefill16 = probe._prefill_fn(16)
    toks16 = jnp.zeros((1, 16), jnp.int32)
    prefill_tok_s = _time_it(
        lambda: prefill16(probe.params, toks16, jnp.int32(15))) / 16.0
    probe.insert(Request(rid=-1, prompt=[1] * 16,
                         max_new_tokens=cache_len - 16))
    probe.decode_round()                       # compile outside the timing
    t0 = time.perf_counter()
    for _ in range(5):
        probe.decode_round()
    decode_round_s = (time.perf_counter() - t0) / 5
    src = session.batch_source()
    session.run(1, src)                        # compile the train step
    t0 = time.perf_counter()
    session.run(1, src, prefetch=0)
    train_epoch_s = time.perf_counter() - t0
    del probe

    costs = dict(prefill_tok_s=prefill_tok_s, decode_round_s=decode_round_s,
                 train_epoch_s=train_epoch_s)
    arrival_gap_s = 10 * decode_round_s
    round_budget_s = max(30 * decode_round_s, 2.5 * train_epoch_s)

    # -- one workload, replayed per lane -----------------------------------
    rng = _random.Random(7)
    prompts = [[rng.randrange(cfg.vocab_size)
                for _ in range(rng.randint(8, 24))]
               for _ in range(n_requests)]
    new_toks = [rng.randint(6, 18) for _ in range(n_requests)]

    def workload():
        return [Request(rid=i, prompt=list(prompts[i]),
                        max_new_tokens=new_toks[i],
                        arrival_s=i * arrival_gap_s)
                for i in range(n_requests)]

    out: dict = {"arch": arch, "mesh": "4x2", "slots": slots,
                 "cache_len": cache_len, "n_requests": n_requests,
                 "measured_costs": costs,
                 "arrival_gap_s": arrival_gap_s,
                 "round_budget_s": round_budget_s,
                 "note": "both lanes replay the same workload on a "
                         "SyntheticClock priced with the measured costs; "
                         "deltas are scheduling policy, not host noise"}

    # fine-tune progress is judged on a *fixed* held-out batch (per-epoch
    # train losses are each on a different minibatch, so their noise —
    # ~0.1 nats here — buries the few-epoch learning signal; the eval
    # batch isolates the parameter movement itself)
    from repro.dist import use_sharding
    from repro.models import lm_loss
    eval_batch = src.batch(10_000)             # off-stream, deterministic
    eval_fn = jax.jit(lambda p, b: lm_loss(p, cfg, b)[0])

    def eval_loss() -> float:
        with use_sharding(mesh):
            return float(eval_fn(session.params, eval_batch))

    out["finetune_eval_loss_before"] = eval_loss()

    # static rebatching lane (initial params; greedy, so the schedule —
    # and therefore every SLO — is independent of the iterate)
    static_reqs = workload()
    static_rep = serve_static(
        session.params, cfg, static_reqs, batch=slots, cache_len=cache_len,
        clock=SyntheticClock(**costs), metrics=ServeMetrics(), mesh=mesh)
    out["static"] = static_rep.summary

    # continuous lane, background fine-tune absorbed into idle budget
    cont_reqs = workload()
    queue = RequestQueue(AdmissionPolicy(cache_len=cache_len))
    for r in cont_reqs:
        queue.push(r)
    engine = SlotEngine(session.params, cfg, slots=slots,
                        cache_len=cache_len, mesh=mesh)
    sched = ServeScheduler(engine, queue, round_budget_s=round_budget_s,
                           clock=SyntheticClock(**costs), session=session,
                           train_epochs=8)
    cont_rep = sched.run()
    out["continuous"] = cont_rep.summary
    out["train_losses"] = sched.metrics.train_losses
    out["finetune_eval_loss_after"] = eval_loss()
    session.close()

    cont, stat = out["continuous"], out["static"]
    out["continuous_beats_static_tokens_per_s"] = bool(
        cont["tokens_per_s"] > stat["tokens_per_s"])
    out["continuous_beats_static_ttft_p99"] = bool(
        cont["ttft_p99_s"] < stat["ttft_p99_s"])
    out["finetune_loss_decreased"] = bool(
        cont_rep.train_epochs >= 1
        and out["finetune_eval_loss_after"]
        < out["finetune_eval_loss_before"])
    return out


def bench_multipod(arch: str, seq_len: int) -> dict:
    """Run :func:`multipod_probe` in a clean 512-device subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.dist_step", "--multipod-probe",
         "--arch", arch, "--seq-len", str(seq_len)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800)
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:]}
    return json.loads(proc.stdout.splitlines()[-1])


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--skip-multipod", action="store_true",
                    help="skip the 512-device lower+compile subprocess")
    ap.add_argument("--multipod-probe", action="store_true",
                    help=argparse.SUPPRESS)   # internal subprocess mode
    args = ap.parse_args(argv)

    if args.multipod_probe:
        print(json.dumps(multipod_probe(args.arch, args.seq_len)))
        return {}

    rec = {
        "name": "dist_step",
        "devices": len(jax.devices()),
        "train_steps": bench_train_steps(args.arch, args.steps,
                                         args.seq_len),
        "gossip_combine": bench_gossip_combine(),
        "dist_dataplane": bench_dataplane(args.arch, args.steps,
                                          args.seq_len),
        "dist_pipelined": {
            "overlap": bench_pipelined(args.arch, args.steps,
                                       args.seq_len),
        },
        "dist_async": bench_async(args.arch, args.steps, args.seq_len),
        "dist_controller": bench_controller(args.arch, args.steps,
                                            args.seq_len),
        "dist_churn": bench_churn(args.arch, args.steps, args.seq_len),
        "dist_serve": bench_serve(args.arch, args.seq_len),
    }
    if not args.skip_multipod:
        rec["dist_pipelined"]["multipod_2x16x16"] = bench_multipod(
            args.arch, args.seq_len)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "BENCH_dist.json").write_text(json.dumps(rec, indent=2))

    ts = rec["train_steps"]
    print("name,us_per_call,derived")
    print(f"dist_exact_step,{ts['exact_step_s'] * 1e6:.0f},1.0")
    for r in (4, 16, 60):
        print(f"dist_gossip_r{r}_step,{ts[f'gossip_r{r}_step_s'] * 1e6:.0f},"
              f"{ts[f'gossip_r{r}_step_s'] / ts['exact_step_s']:.2f}")
    dp = rec["dist_dataplane"]
    for label, row in dp["overlap"].items():
        print(f"dist_dataplane_{label},"
              f"{1e6 / row['prefetched_steps_per_s']:.0f},"
              f"{row['speedup']:.3f}")
    for r, row in rec["dist_pipelined"]["overlap"].items():
        if not isinstance(row, dict):
            continue
        print(f"dist_pipelined_{r}_step,{row['pipelined_step_s'] * 1e6:.0f},"
              f"{row['overlap_ratio']:.3f}")
    seq_wall = rec["dist_async"]["sequential"]["sim_epoch_wall_s"]
    for label, row in rec["dist_async"].items():
        if not (isinstance(row, dict) and "sim_epoch_wall_s" in row):
            continue
        print(f"dist_async_{label},{row['sim_epoch_wall_s'] * 1e6:.0f},"
              f"{seq_wall / row['sim_epoch_wall_s']:.3f}")
    ctl = rec["dist_controller"]
    best_wall = ctl[ctl["best_static"]]["sim_wall_per_epoch_s"]
    for label, row in ctl.items():
        if not (isinstance(row, dict) and "sim_wall_per_epoch_s" in row):
            continue
        print(f"dist_controller_{label},"
              f"{row['sim_wall_per_epoch_s'] * 1e6:.0f},"
              f"{best_wall / row['sim_wall_per_epoch_s']:.3f}")
    ch = rec["dist_churn"]
    for label in ("nochurn_uncoded", "nochurn_coded", "churn_uncoded",
                  "churn_coded"):
        row = ch[label]
        print(f"dist_churn_{label},{row['sim_epoch_wall_s'] * 1e6:.0f},"
              f"{row['loss_tail']:.4f}")
    fid = ch["estimator_fidelity"]
    for coding in ("uncoded", "coded"):
        print(f"dist_churn_{coding}_coverage,0,"
              f"{fid[f'{coding}_coverage']:.4f}")
    fp = ch["survivor_fast_path"]
    print(f"dist_churn_relayout_combine,{fp['relayout_combine_s'] * 1e6:.0f},"
          f"{fp['dense_fallback_combine_s'] / fp['relayout_combine_s']:.3f}")
    sv = rec["dist_serve"]
    if "skipped" not in sv:
        for lane in ("continuous", "static"):
            row = sv[lane]
            print(f"dist_serve_{lane},{row['span_s'] * 1e6:.0f},"
                  f"{row['tokens_per_s']:.1f}")
        print(f"dist_serve_ttft_p99,{sv['continuous']['ttft_p99_s'] * 1e6:.0f},"
              f"{sv['static']['ttft_p99_s'] / sv['continuous']['ttft_p99_s']:.3f}")
        print(f"dist_serve_finetune_epochs,{len(sv['train_losses'])},"
              f"{1.0 if sv['finetune_loss_decreased'] else 0.0}")
    print(f"[ok] wrote {outdir / 'BENCH_dist.json'}")
    return rec


if __name__ == "__main__":
    main()
