"""HLO breakdown tool for §Perf hillclimbing (CPU dry-run profiling).

Lowers one (arch x shape) on the production mesh (depth-p unrolled variant,
same as the roofline measurement), compiles, and prints:

  * cost_analysis totals,
  * top ops by output bytes (what dominates the memory term),
  * every collective with shape + bytes (what dominates the collective term).

Usage:
    PYTHONPATH=src python -m benchmarks.hlo_analyze --arch qwen3-moe-30b-a3b \
        --shape train_4k [--top 25] [--layers 1]
"""
from __future__ import annotations

# must run before jax import (see repro.launch.dryrun)
from repro.launch import dryrun as D  # noqa: F401  (sets XLA_FLAGS)

import argparse      # noqa: E402
import collections   # noqa: E402
import re            # noqa: E402

import numpy as np   # noqa: E402

from repro.configs import SHAPES, get_config            # noqa: E402
from repro.dist import use_sharding                     # noqa: E402
from repro.models.common import unrolled_loops          # noqa: E402

_SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"=\s*\w+\[[\d,]*\]\S*\s+(\S+?)\(")


def tensor_bytes(dt: str, dims: str) -> int:
    nbytes = D._DTYPE_BYTES.get(dt, 4)
    size = 1
    for d in dims.split(","):
        if d:
            size *= int(d)
    return size * nbytes


def analyze(arch: str, shape_name: str, layers: int, top: int,
            multi_pod: bool = False):
    shape = SHAPES[shape_name]
    cfg = get_config(arch, shape=shape_name)
    cfg = D._depth_variant(cfg, layers, shape.seq_len)
    mesh = D._mesh(multi_pod)
    with use_sharding(mesh), unrolled_loops():
        lowered = D._lower_combo(cfg, shape, mesh)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    print(f"== {arch} x {shape_name} (layers={layers}) "
          f"mesh={'2x16x16' if multi_pod else '16x16'}")
    print(f"flops/chip={ca.get('flops', 0):.4g}  "
          f"bytes/chip={ca.get('bytes accessed', 0):.4g}")

    text = compiled.as_text()
    by_op = collections.Counter()
    by_op_count = collections.Counter()
    colls = []
    for line in text.splitlines():
        m = _SHAPE_RE.match(line)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        if dt not in D._DTYPE_BYTES:
            continue
        nb = tensor_bytes(dt, dims)
        om = _OP_RE.search(line)
        op = om.group(1) if om else "?"
        by_op[op] += nb
        by_op_count[op] += 1
        if op.split(".")[0] in ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"):
            colls.append((op, dt, dims, nb))

    print(f"\n-- top {top} ops by summed output bytes --")
    for op, nb in by_op.most_common(top):
        print(f"{nb / 2**20:12.1f} MiB  x{by_op_count[op]:<5d} {op}")

    print("\n-- collectives --")
    agg = collections.Counter()
    cnt = collections.Counter()
    for op, dt, dims, nb in colls:
        key = (op.split(".")[0], dt, dims)
        agg[key] += nb
        cnt[key] += 1
    for (op, dt, dims), nb in agg.most_common(40):
        print(f"{nb / 2**20:12.2f} MiB  x{cnt[(op, dt, dims)]:<4d} "
              f"{op:20s} {dt}[{dims}]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    analyze(args.arch, args.shape, args.layers, args.top, args.multi_pod)


if __name__ == "__main__":
    main()
