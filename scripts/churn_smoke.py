#!/usr/bin/env python
"""CI smoke for straggler-proof fleets (fast lane of scripts/verify.sh).

End-to-end checks that the churn machinery is wired, not just
importable — on 8 forced host devices so real membership changes happen:

  1. **Churn run** — a short ``AMBSession.run(faults=...)`` under
     :class:`repro.faults.PoissonChurn` with coded redundancy (rho = 2)
     must apply at least one membership change, keep every loss finite,
     and keep the gossip operator on the survivor-relayout fast path
     (``SurvivorTaps``, never the dense masked fallback) whenever >= 2
     workers survive.
  2. **Bit-exact restore mid-churn** — saving after k churned epochs,
     restoring, and continuing under a fresh injector over the *same*
     fault model must reproduce the uninterrupted run's losses exactly:
     fault models are pure in the epoch index, so the trajectory —
     membership masks included — replays bit-for-bit.
  3. **Edge cases** — an all-inactive mask is rejected loudly; a
     single-survivor fleet degenerates to identity consensus (no
     permutes) and still steps.
"""
from __future__ import annotations

import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                           # noqa: E402

from repro.api import (AMBSession, ClockSpec, ConsensusSpec,  # noqa: E402
                       TrainSpec)
from repro.dist import SurvivorTaps          # noqa: E402
from repro.dist.amb import strategy_from_config  # noqa: E402
from repro.faults import FaultInjector, PoissonChurn  # noqa: E402

TRAIN = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=16,
                  batch_per_worker=2, data=8, redundancy=2)
CONS = ConsensusSpec(consensus="gossip", gossip_rounds=2)
MODEL = PoissonChurn(leave_rate=0.4, rejoin_rate=0.6, seed=5)
EPOCHS = 6


def _session() -> AMBSession:
    return AMBSession(TRAIN, ClockSpec(kind="simulated"), CONS)


def run() -> None:
    # 1. churned run: finite losses, real membership changes, fast path
    sess = _session()
    inj = FaultInjector(MODEL)
    losses: list = []

    def on_step(step, m):
        losses.append(float(m["loss"]))
        # the active epoch's operator (same construction the protocol
        # compiled): churned masks must ride the survivor-relayout taps
        strat = strategy_from_config(sess.protocol.amb, sess.mesh)
        if strat.active is not None and sum(strat.active) >= 2:
            assert isinstance(strat.taps, SurvivorTaps), \
                "churned gossip fell off the survivor-relayout fast path"

    sess.run(EPOCHS, prefetch=0, faults=inj, on_step=on_step)
    assert len(losses) == EPOCHS and np.isfinite(losses).all(), losses
    assert inj.membership_changes >= 1, "churn model never changed the fleet"

    # 2. save mid-churn -> restore -> continue == uninterrupted run
    half = EPOCHS // 2
    sess2 = _session()
    sess2.run(half, prefetch=0, faults=FaultInjector(MODEL))
    with tempfile.TemporaryDirectory() as d:
        sess2.save(d)
        sess2.close()
        resumed = AMBSession.restore(d)
    got: list = []
    resumed.run(EPOCHS - half, prefetch=0, faults=FaultInjector(MODEL),
                on_step=lambda s, m: got.append(float(m["loss"])))
    assert got == losses[half:], \
        f"restore diverged under churn: {got} != {losses[half:]}"
    resumed.close()

    # 3. edge cases: all-inactive rejected; single survivor still steps
    try:
        sess.set_active([False] * 8)
        raise AssertionError("all-inactive mask was accepted")
    except ValueError:
        pass
    sess.set_active([False] * 7 + [True])
    strat = strategy_from_config(sess.protocol.amb, sess.mesh)
    assert strat.identity and strat.taps is None
    m = sess.step(sess.batch_source().batch(EPOCHS))
    assert np.isfinite(m["loss"]) and m["b"][:7].sum() == 0
    sess.close()

    print(f"[ok] churn smoke: {EPOCHS} epochs, "
          f"{inj.membership_changes} membership changes, "
          f"bit-exact restore mid-churn, single-survivor identity")


if __name__ == "__main__":
    run()
