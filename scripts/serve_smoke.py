#!/usr/bin/env python
"""CI smoke for the serving tier (fast lane of scripts/verify.sh).

End-to-end on the tiny smoke arch, deterministic synthetic clock:

  1. **Continuous batching correctness** — staggered arrivals with
     heterogeneous prompt lengths through the ``SlotEngine`` +
     ``ServeScheduler`` produce, per request, exactly the tokens the
     static rebatching reference produces (greedy, same params): the
     slot scatter, per-slot positions and bucket-padded prefill change
     the schedule, never the math.
  2. **Budget interleave** — background AMB fine-tune epochs run through
     the same ``AMBSession`` inside idle round budget; serving must
     finish every request AND at least one train epoch must land, with
     the session's loss recorded.
  3. **Metrics flush** — the SLO records (TTFT/TPOT/latency) reach the
     MetricsLogger JSONL even though no explicit close is issued before
     the check (the decode-only flush bug this PR fixes).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                   # noqa: E402

from repro.api import (AMBSession, ClockSpec, ConsensusSpec,  # noqa: E402
                       TrainSpec)
from repro.metrics import MetricsLogger      # noqa: E402
from repro.models.common import ArchConfig   # noqa: E402
from repro.serve import (AdmissionPolicy, Request, RequestQueue,  # noqa: E402
                         ServeMetrics, ServeScheduler, SlotEngine,
                         SyntheticClock, static_generate,
                         synthetic_requests)


def _session():
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return AMBSession(TrainSpec(batch_per_worker=2, seq_len=8),
                      ClockSpec(kind="simulated"), ConsensusSpec(),
                      mesh=mesh, cfg=cfg)


def run() -> None:
    session = _session()
    cfg, mesh, params = session.cfg, session.mesh, session.params
    cache_len = 24
    reqs = synthetic_requests(6, vocab_size=cfg.vocab_size, prompt_len=8,
                              prompt_jitter=4, max_new_tokens=5,
                              arrival_gap_s=0.01, seed=3)
    clock_costs = dict(prefill_tok_s=0.001, decode_round_s=0.005,
                       train_epoch_s=0.02)

    # 1. parity: staggered continuous batching (no training, so params
    #    are frozen) must match the static reference token-for-token
    queue = RequestQueue(AdmissionPolicy(cache_len=cache_len))
    for r in reqs:
        queue.push(r)
    assert len(queue) == len(reqs), "smoke workload must be admissible"
    engine = SlotEngine(params, cfg, slots=2, cache_len=cache_len, mesh=mesh)
    sched = ServeScheduler(engine, queue, round_budget_s=0.06,
                           clock=SyntheticClock(**clock_costs))
    report = sched.run()
    assert report.summary["n_requests"] == len(reqs), report.summary
    assert report.summary["ttft_p99_s"] > 0 and \
        report.summary["tokens_per_s"] > 0, report.summary
    static = [Request(rid=r.rid, prompt=list(r.prompt),
                      max_new_tokens=r.max_new_tokens) for r in reqs]
    static_generate(params, cfg, static, cache_len=cache_len, mesh=mesh)
    for a, b in zip(reqs, static):
        assert a.out_tokens == b.out_tokens, (a.rid, a.out_tokens,
                                              b.out_tokens)

    # 2 + 3. fine-tune interleave on the same session (serving decodes
    #    the live primal) + SLO/train records flushed to JSONL
    reqs2 = synthetic_requests(6, vocab_size=cfg.vocab_size, prompt_len=8,
                               prompt_jitter=4, max_new_tokens=5,
                               arrival_gap_s=0.01, seed=4)
    queue2 = RequestQueue(AdmissionPolicy(cache_len=cache_len))
    for r in reqs2:
        queue2.push(r)
    path = os.path.join(tempfile.mkdtemp(prefix="serve_smoke_"),
                        "serve.jsonl")
    logger = MetricsLogger(path)
    engine2 = SlotEngine(session.params, cfg, slots=2, cache_len=cache_len,
                         mesh=mesh)
    sched2 = ServeScheduler(engine2, queue2, round_budget_s=0.06,
                            clock=SyntheticClock(**clock_costs),
                            session=session, train_epochs=3,
                            metrics=ServeMetrics(logger))
    report2 = sched2.run()
    assert report2.summary["n_requests"] == len(reqs2), report2.summary
    assert report2.train_epochs >= 1, "no fine-tune epoch absorbed"

    # the per-write flush (plus idempotent close) means records are on
    # disk now, before any close
    recs = [json.loads(line) for line in open(path)]
    kinds = {r.get("kind") for r in recs}
    assert "request" in kinds and "train" in kinds, kinds
    logger.close()
    logger.close()                            # idempotent

    session.close()
    print(f"[ok] serve smoke: {len(reqs)} staggered requests over 2 slots "
          f"== static reference token-for-token; "
          f"{report2.train_epochs} AMB epoch(s) absorbed "
          f"(loss {sched2.metrics.train_losses[-1]:.4f}); "
          f"SLO JSONL flushed ({len(recs)} records)")


if __name__ == "__main__":
    run()
