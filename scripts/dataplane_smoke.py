#!/usr/bin/env python
"""CI smoke for the data plane (fast lane of scripts/verify.sh).

End-to-end checks that the step-time critical path is actually wired,
not just importable:

  1. **Prefetched run** — a short ``AMBSession.run`` on a 1x1 mesh draws
     per-worker stream shards through a background
     :class:`repro.data.Prefetcher` and matches the synchronous
     (``prefetch=0``) loop loss-for-loss — token draws are
     deterministic, so any divergence is a data-plane ordering bug.
  2. **Donation** — after a step, every leaf of the pre-step TrainState
     must be freed (``donate_argnums=0`` aliasing held; the old iterate
     was rewritten in place, not shadowed).
  3. **Kernel routing** — on a CPU host the router must resolve the
     compiled jnp reference (never interpret-mode Pallas on the hot
     path), and the ``REPRO_KERNELS`` override must take.
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                   # noqa: E402

from repro.api import (AMBSession, ClockSpec, ConsensusSpec,  # noqa: E402
                       TrainSpec)
from repro.kernels import router             # noqa: E402
from repro.models.common import ArchConfig   # noqa: E402


def _session():
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=64, q_chunk=16, kv_chunk=16,
                     mxu_f32_accum=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return AMBSession(TrainSpec(batch_per_worker=2, seq_len=8),
                      ClockSpec(kind="simulated"), ConsensusSpec(),
                      mesh=mesh, cfg=cfg)


def run() -> None:
    # 1. prefetched vs sync: identical losses, identical step counters
    losses_pre, losses_sync = [], []
    sA, sB = _session(), _session()
    sA.run(3, prefetch=2, on_step=lambda s, m: losses_pre.append(m["loss"]))
    sB.run(3, prefetch=0, on_step=lambda s, m: losses_sync.append(m["loss"]))
    assert losses_pre == losses_sync, (losses_pre, losses_sync)
    assert sA.steps_done == sB.steps_done == 3

    # 2. donation: the pre-step state's buffers are actually freed
    old = sA.state
    sA.run(1)
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old)), \
        "pre-step TrainState still live: donation not in effect"

    # 3. routing: never interpret on the CPU hot path; override takes
    resolved = router.resolve()
    backend = jax.default_backend()
    if backend not in ("tpu", "gpu"):
        assert resolved == "ref", (backend, resolved)
    assert resolved != "pallas_interpret"
    router.set_mode("pallas_interpret")      # explicit override wins
    assert router.resolve() == "pallas_interpret"
    router.set_mode(None)
    assert router.resolve() == resolved

    print(f"[ok] dataplane smoke: prefetched==sync over 3 steps "
          f"(loss {losses_pre[-1]:.4f}), donation freed the old state, "
          f"kernel routing {backend} -> {resolved}")


if __name__ == "__main__":
    run()
