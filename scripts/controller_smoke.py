#!/usr/bin/env python
"""CI smoke for the online controller (fast lane of scripts/verify.sh).

Runs a short ``repro.launch.train`` session on forced host devices with
``--controller`` and a deliberately mis-tuned (10x over-provisioned)
simulated compute budget, then asserts from the metrics JSONL that the
controller issued at least one non-trivial
:class:`repro.control.ControlAction` — i.e. the telemetry -> policy ->
actuation loop is alive end to end, not just importable.
"""
from __future__ import annotations

import os
import sys
import tempfile

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main          # noqa: E402
from repro.metrics import read_metrics       # noqa: E402


def run() -> None:
    path = os.path.join(tempfile.mkdtemp(), "controller_smoke.jsonl")
    steps = 5
    main(["--smoke", "--seq-len", "16", "--batch-per-worker", "2",
          "--data", "4", "--model", "2", "--steps", str(steps),
          "--sim-clock", "--compute-time", "40.0", "--comm-time", "0.5",
          "--consensus", "gossip", "--gossip-rounds", "2",
          "--controller", "--controller-interval", "1",
          "--controller-warmup", "2", "--metrics", path])
    recs = read_metrics(path)
    assert len(recs) == steps, (len(recs), steps)
    actions = [r["action"] for r in recs if "action" in r]
    nontrivial = [a for a in actions
                  if a.get("budget") is not None
                  or a.get("staleness") is not None
                  or a.get("b_target") is not None]
    assert nontrivial, "controller issued no non-trivial action " \
                       "on a 10x mis-tuned budget"
    print(f"[ok] controller smoke: {len(nontrivial)} non-trivial "
          f"action(s); last: {nontrivial[-1]['reason']}")


if __name__ == "__main__":
    run()
