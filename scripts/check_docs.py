#!/usr/bin/env python3
"""Docs reference check: README/docs must not drift from the code.

Scans ``README.md`` and ``docs/*.md`` for three kinds of references and
fails if any points at something that does not exist:

  * **module paths** — every ``repro.foo.bar[.symbol]`` mention must
    resolve to a module file under ``src/`` (package ``__init__.py``
    included), and a trailing ``.symbol`` must appear as a word in that
    module's source;
  * **CLI flags** — every ``--flag`` mention must be declared by some
    ``add_argument("--flag" ...)`` under ``src/``, ``benchmarks/`` or
    ``examples/`` (underscore flags like XLA's are exempt — they are
    not argparse surface);
  * **local paths** — markdown links and backtick-quoted paths (with a
    ``/`` and a known extension) must exist on disk.

Pure text analysis — no jax import, runs in milliseconds.  Part of
``scripts/verify.sh`` (both lanes).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src"

MODULE_RE = re.compile(r"\brepro(?:\.[A-Za-z_]\w*)+")
FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9_-]*")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#:\s]+)\)")
PATH_RE = re.compile(r"`([\w.-]+(?:/[\w.<>-]+)+\.(?:py|md|sh|json|txt))`")
ADD_ARG_RE = re.compile(r"add_argument\(\s*['\"](--[a-z0-9-]+)['\"]")


def doc_files() -> list[Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


SH_FLAG_RE = re.compile(r"^\s*(--[a-z0-9-]+)\)", re.MULTILINE)


def declared_flags() -> set[str]:
    flags = set()
    for base in (SRC, ROOT / "benchmarks", ROOT / "examples"):
        for py in base.rglob("*.py"):
            flags.update(ADD_ARG_RE.findall(py.read_text()))
    for sh in (ROOT / "scripts").glob("*.sh"):   # verify.sh case labels
        flags.update(SH_FLAG_RE.findall(sh.read_text()))
    return flags


def resolve_module(dotted: str) -> str | None:
    """Error string if ``dotted`` does not resolve, else None."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        base = SRC / Path(*parts[:cut])
        mod = base.with_suffix(".py")
        pkg = base / "__init__.py"
        f = mod if mod.exists() else (pkg if pkg.exists() else None)
        if f is None:
            continue
        rest = parts[cut:]
        if not rest:
            return None
        if len(rest) > 1:
            return (f"{dotted}: {'.'.join(parts[:cut])} resolves to "
                    f"{f.relative_to(ROOT)} but the remainder "
                    f"{'.'.join(rest)} nests too deep")
        # the symbol must be *defined or imported* there, not merely a
        # word in prose (a docstring mention would false-pass artifacts
        # like "repro.api.The" from sentence-boundary regex captures)
        sym = re.escape(rest[0])
        defined = re.search(
            rf"(?m)^\s*(?:def|class)\s+{sym}\b"
            rf"|^(?:from\s+\S+\s+)?import\s.*\b{sym}\b"
            rf"|^{sym}\s*[:=]", f.read_text())
        if defined:
            return None
        return (f"{dotted}: symbol {rest[0]!r} is not defined, assigned, "
                f"or imported in {f.relative_to(ROOT)}")
    return f"{dotted}: no module file under src/"


def check() -> int:
    flags = declared_flags()
    errors = []
    for doc in doc_files():
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for dotted in sorted(set(MODULE_RE.findall(text))):
            err = resolve_module(dotted)
            if err:
                errors.append(f"{rel}: {err}")
        for flag in sorted(set(FLAG_RE.findall(text))):
            if flag.startswith("--xla"):   # XLA flags, not argparse
                continue
            if flag not in flags:
                errors.append(f"{rel}: CLI flag {flag} is not declared by "
                              f"any add_argument in src/, benchmarks/ or "
                              f"examples/")
        refs = set(LINK_RE.findall(text)) | set(PATH_RE.findall(text))
        for ref in sorted(refs):
            if "<" in ref:             # placeholder paths like step_<n>/
                continue
            if not ((doc.parent / ref).exists() or (ROOT / ref).exists()):
                errors.append(f"{rel}: referenced path {ref} does not exist")
    if errors:
        print(f"[docs-check] {len(errors)} stale reference(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    n = len(doc_files())
    print(f"[docs-check] OK: {n} docs, {len(flags)} declared flags")
    return 0


if __name__ == "__main__":
    sys.exit(check())
