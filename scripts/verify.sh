#!/usr/bin/env bash
# Tier-1 verification, reproducible offline: force the host (CPU) backend
# so the suite behaves identically with or without accelerators attached.
# Mesh-heavy subprocess tests force their own device counts internally.
#
#   scripts/verify.sh                # full tier-1 run (docs check + API
#                                    # smoke + pytest)
#   scripts/verify.sh --fast         # fast lane: skip the mesh-heavy
#                                    # subprocess tests (-m 'not slow');
#                                    # docs check + smoke still run
#   scripts/verify.sh -m 'not slow'  # extra pytest args pass through
#   scripts/verify.sh --no-smoke ... # skip the API smoke stage
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS=cpu
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# NB: the persistent XLA compilation cache (JAX_COMPILATION_CACHE_DIR)
# is deliberately NOT enabled here.  On this container's jaxlib (0.4.36,
# CPU) the cache's serializable-executable compile path mishandles
# input-output aliasing for the session's donated train-step
# executables: tests/test_async.py::test_restore_roundtrip_tiny goes
# NaN and glibc reports heap corruption ("corrupted size vs.
# prev_size") with the cache on, and is clean with it off — or with
# donation off.  Donation is the win we keep; re-enable the cache only
# after a jaxlib upgrade proves this combination clean.
unset JAX_COMPILATION_CACHE_DIR

pytest_args=()
smoke=1
for arg in "$@"; do
  case "$arg" in
    --fast)     pytest_args+=(-m "not slow") ;;
    --no-smoke) smoke=0 ;;
    *)          pytest_args+=("$arg") ;;
  esac
done

echo "== docs check: python scripts/check_docs.py =="
# README/docs module paths, CLI flags, and local links must exist
python scripts/check_docs.py

if [[ "$smoke" == 1 ]]; then
  # runs in the --fast lane too: the example IS the API's executable doc
  echo "== API smoke: python -m examples.api_session --smoke =="
  # under JAX_PLATFORMS=cpu the example forces its own 8 host devices
  # via XLA_FLAGS, so this behaves identically with or without
  # accelerators attached
  python -m examples.api_session --smoke

  # controller smoke (fast lane too): a short --controller run on forced
  # host devices must emit at least one non-trivial ControlAction
  echo "== controller smoke: python scripts/controller_smoke.py =="
  python scripts/controller_smoke.py

  # dataplane smoke (fast lane too): prefetched run == sync run,
  # TrainState donation in effect, kernel router resolves the compiled
  # jnp reference on CPU (never interpret-mode Pallas on the hot path)
  echo "== dataplane smoke: python scripts/dataplane_smoke.py =="
  python scripts/dataplane_smoke.py

  # churn smoke (fast lane too): Poisson churn + coded redundancy on 8
  # forced host devices — finite losses, survivor-relayout fast path,
  # bit-exact save -> restore mid-churn, single-survivor identity
  echo "== churn smoke: python scripts/churn_smoke.py =="
  python scripts/churn_smoke.py

  # serve smoke (fast lane too): staggered continuous batching == static
  # reference token-for-token, background AMB fine-tune epoch absorbed
  # into the round budget, SLO JSONL flushed
  echo "== serve smoke: python scripts/serve_smoke.py =="
  python scripts/serve_smoke.py
fi

echo "== pytest ${pytest_args[*]:-} =="
# ${arr[@]+...} guard: empty-array expansion is an unbound-variable error
# under `set -u` on bash < 4.4 (stock macOS bash 3.2)
exec python -m pytest -x -q ${pytest_args[@]+"${pytest_args[@]}"}
