#!/usr/bin/env bash
# Tier-1 verification, reproducible offline: force the host (CPU) backend
# so the suite behaves identically with or without accelerators attached.
# Mesh-heavy subprocess tests force their own device counts internally.
#
#   scripts/verify.sh              # full tier-1 run
#   scripts/verify.sh -m 'not slow'  # skip the mesh-heavy subprocess tests
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS=cpu
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
