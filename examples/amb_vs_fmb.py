"""Reproduce the paper's Figure-1 comparison as CSV curves.

Writes error-vs-wall-time for AMB and FMB on both of the paper's workloads
(linear regression, logistic regression) to artifacts/fig1_{a,b}.csv.

    PYTHONPATH=src python examples/amb_vs_fmb.py
"""
import csv
from pathlib import Path

import jax
import numpy as np

from repro.core import (BetaSchedule, EngineConfig, ShiftedExponential,
                        amb_budget_from_fmb, run_amb, run_fmb)
from repro.core.objectives import LinearRegression, LogisticRegression


def curves(obj, sample_args, eval_fn, n, b_global, epochs, out_csv):
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=b_global // n)
    t_budget = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t_budget, comm_time=0.3 * t_budget,
        fmb_batch_per_node=b_global // n, graph="paper",
        consensus_rounds=5, beta=BetaSchedule(k=1.0, mu=float(b_global)))
    kw = dict(epochs=epochs, key=jax.random.PRNGKey(0),
              sample_args=sample_args, eval_fn=eval_fn)
    h_amb = run_amb(obj, model, cfg, **kw)
    h_fmb = run_fmb(obj, model, cfg, **kw)

    Path(out_csv).parent.mkdir(parents=True, exist_ok=True)
    with open(out_csv, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["epoch", "amb_wall_s", "amb_loss", "fmb_wall_s",
                    "fmb_loss"])
        for t in range(epochs):
            w.writerow([t, float(h_amb.wall_time[t]),
                        float(h_amb.eval_loss[t]),
                        float(h_fmb.wall_time[t]),
                        float(h_fmb.eval_loss[t])])
    ratio = float(h_fmb.wall_time[-1] / h_amb.wall_time[-1])
    print(f"{out_csv}: FMB/AMB wall ratio = {ratio:.2f}")
    return ratio


def main():
    # Fig 1(a): linear regression (paper d=1e5; d=512 here, same dynamics)
    obj = LinearRegression(dim=512)
    w_star = jax.random.normal(jax.random.PRNGKey(42), (512,))
    curves(obj, (w_star,), lambda w: obj.population_loss(w, w_star),
           n=10, b_global=600, epochs=100,
           out_csv="artifacts/fig1_a_linreg.csv")

    # Fig 1(b): logistic regression on the MNIST-like mixture
    obj2 = LogisticRegression(dim=64, num_classes=10)
    means = obj2.make_class_means(jax.random.PRNGKey(3))
    eval_batch = obj2.sample(jax.random.PRNGKey(9), (2048,), means)
    curves(obj2, (means,), lambda w: obj2.loss(w, eval_batch),
           n=10, b_global=8000, epochs=100,
           out_csv="artifacts/fig1_b_logreg.csv")


if __name__ == "__main__":
    main()
