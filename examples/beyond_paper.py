"""Beyond-paper AMB variants, side by side with the paper's protocol.

Runs the paper's linear-regression workload (Fig. 1a setup) under:

  * FMB           — fixed minibatch (the paper's baseline),
  * AMB           — the paper's protocol (faithful reproduction),
  * AMB-pipelined — consensus window overlapped with gradient compute,
  * AMB-q8        — 8-bit stochastically-quantized gossip (4x rounds / T_c).

Usage:  PYTHONPATH=src python examples/beyond_paper.py [--epochs 120]
"""
import argparse

import jax
import numpy as np

from repro.core import (BetaSchedule, EngineConfig, ShiftedExponential,
                        amb_budget_from_fmb, run_amb, run_fmb)
from repro.core.extensions import run_amb_pipelined, run_amb_quantized
from repro.core.objectives import LinearRegression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=120)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=10)
    args = ap.parse_args()

    n, b_global = args.nodes, 600
    obj = LinearRegression(dim=args.dim)
    w_star = jax.random.normal(jax.random.PRNGKey(42), (args.dim,))
    eval_fn = lambda w: obj.population_loss(w, w_star)
    model = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=60)
    t_budget = amb_budget_from_fmb(model, n, b_global)
    cfg = EngineConfig(
        n=n, b_max=4 * (b_global // n), chunk=b_global // n,
        compute_time=t_budget, comm_time=0.3 * t_budget,
        fmb_batch_per_node=b_global // n, graph="paper",
        consensus_rounds=5, beta=BetaSchedule(k=1.0, mu=float(b_global)))
    kw = dict(epochs=args.epochs, key=jax.random.PRNGKey(0),
              sample_args=(w_star,), eval_fn=eval_fn,
              f_star=0.5 * obj.noise_var)

    runs = {
        "FMB (paper baseline)": run_fmb(obj, model, cfg, **kw),
        "AMB (paper)": run_amb(obj, model, cfg, **kw),
        "AMB-pipelined": run_amb_pipelined(obj, model, cfg, **kw),
        "AMB-q8": run_amb_quantized(obj, model, cfg, bits=8, **kw),
    }

    print(f"{'variant':24s} {'wall(s)':>9s} {'final_loss':>12s} "
          f"{'mean_batch':>11s} {'mean_eps':>10s}")
    for name, h in runs.items():
        print(f"{name:24s} {float(h.wall_time[-1]):9.1f} "
              f"{float(h.eval_loss[-1]):12.3e} "
              f"{float(h.global_batch.mean()):11.1f} "
              f"{float(h.consensus_eps.mean()):10.2e}")

    # time-to-target comparison
    l0 = float(runs["AMB (paper)"].eval_loss[0])
    lend = max(float(h.eval_loss[-1]) for h in runs.values())
    target = lend + 0.05 * (l0 - lend)
    print(f"\ntime to reach loss <= {target:.3e}:")
    for name, h in runs.items():
        loss = np.asarray(h.eval_loss)
        wall = np.asarray(h.wall_time)
        hit = np.nonzero(loss <= target)[0]
        t = float(wall[hit[0]]) if len(hit) else float("inf")
        print(f"  {name:24s} {t:9.1f} s")


if __name__ == "__main__":
    main()
