"""Batched serving example: prefill a batch of prompts, decode new tokens.

Exercises the production serving path (prefill -> DecodeState -> decode_step)
with a sliding-window variant to show O(window) long-context decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import decode_step, init_params, prefill


def main():
    cfg = dataclasses.replace(smoke_config("qwen3-8b"), sliding_window=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch_size, prompt_len, new_tokens = 4, 48, 24

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch_size, prompt_len), 0, cfg.vocab_size)
    prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b,
                                              extra_capacity=new_tokens))
    step_fn = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))

    t0 = time.time()
    logits, state = prefill_fn(params, {"tokens": prompts})
    logits.block_until_ready()
    print(f"prefill {batch_size}x{prompt_len} in {time.time() - t0:.2f}s "
          f"(ring cache width {cfg.sliding_window} — O(window) memory)")

    tok = jnp.argmax(logits, axis=-1)
    generated = [tok]
    t0 = time.time()
    for _ in range(new_tokens - 1):
        logits, state = step_fn(params, state, tok)
        tok = jnp.argmax(logits, axis=-1)
        generated.append(tok)
    tok.block_until_ready()
    dt = time.time() - t0
    print(f"decoded {new_tokens} rounds x {batch_size} requests in {dt:.2f}s"
          f" ({new_tokens * batch_size / dt:.0f} tok/s on CPU)")
    gen = jnp.stack(generated, axis=1)
    for i in range(batch_size):
        print(f"request {i}: {gen[i][:12].tolist()} ...")


if __name__ == "__main__":
    main()
