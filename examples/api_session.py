"""Programmatic use of ``repro.api``: one AMBSession for train + serve.

Demonstrates the whole Session surface on 8 simulated host devices:

  1. specs — build ``TrainSpec`` / ``ClockSpec`` / ``ConsensusSpec``,
     round-trip them through JSON (what a job file would store),
  2. train — ``session.run(steps)`` under the paper's fixed-time
     contract (simulated straggler clock, torus gossip consensus,
     AMB-DG async epochs: two consensus payloads in flight), fed by the
     prefetched data plane: per-worker LM-stream shards built on a
     background thread and device-put ahead of the step,
  3. elastic membership — ``session.set_active(mask)`` drops a worker
     mid-run (its b_i(t) pins to 0, in-flight consensus drains, and the
     gossip taps rebuild on the active subgraph), then re-admits it,
  4. serve — ``session.flush()`` + ``session.params`` hand the trained
     primal to greedy decode,
  5. checkpoint + restore — ``session.save(dir)`` then
     ``AMBSession.restore(dir)`` resumes params, dual state, and the
     step counter exactly.

    PYTHONPATH=src python -m examples.api_session --smoke
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse          # noqa: E402
import tempfile          # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import (AMBSession, ClockSpec, ConsensusSpec,  # noqa: E402
                       TrainSpec)
from repro.dist import use_sharding                           # noqa: E402
from repro.models import decode_step, prefill                 # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps, reduced config)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args(argv)
    steps = args.steps if args.steps is not None else (6 if args.smoke
                                                       else 30)

    # 1. specs: frozen, JSON-round-trippable configuration
    train = TrainSpec(arch="qwen2-1.5b", smoke=True, seq_len=32,
                      batch_per_worker=2, data=4, model=2)
    clock = ClockSpec(kind="simulated")          # paper-evaluation clock
    consensus = ConsensusSpec(consensus="gossip", graph="torus",
                              gossip_rounds=4, async_epochs=True,
                              staleness=2)       # AMB-DG delayed epochs
    assert TrainSpec.from_json(train.to_json()) == train
    print("specs:", train.to_json())

    session = AMBSession(train, clock, consensus)
    print(f"mesh {dict(session.mesh.shape)} -> {session.n_workers} workers, "
          f"global batch {session.global_batch}")

    # 2. train under the fixed-time contract, fed by the prefetched
    # data plane (the session's default source: worker i draws node i's
    # shard of the LM token stream)
    session.run(steps, on_step=lambda s, m: print(
        f"step {s - 1:3d} loss {m['loss']:.4f} "
        f"b(t)={m['global_batch']:.0f} T={m['budget_s']:.3f}s"))

    # 3. elastic membership: worker 2 leaves (spot preemption), rejoins.
    # session.step(batch) stays the single-epoch primitive for callers
    # that hand-build batches — here, straddling membership changes
    source = session.batch_source()
    mask = session.active
    mask[2] = False
    session.set_active(mask)
    m = session.step(source.batch(session.steps_done))
    assert m["b"][2] == 0, "dropped worker must contribute b_i(t) = 0"
    print(f"worker 2 dropped: b(t) per worker = {m['b'].tolist()}")
    session.set_active([True] * session.n_workers)
    m = session.step(source.batch(session.steps_done))
    print(f"worker 2 rejoined: b(t) per worker = {m['b'].tolist()}")

    # 4. serve from the same session: flush in-flight consensus, decode
    session.flush()
    params = session.params
    cfg = session.cfg
    with use_sharding(session.mesh):
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        logits, state = jax.jit(
            lambda p, b: prefill(p, cfg, b, extra_capacity=8))(
                params, {"tokens": toks})
        tok = jnp.argmax(logits, axis=-1)
        dec = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))
        out = [tok]
        for _ in range(7):
            logits, state = dec(params, state, tok)
            tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
        gen = jnp.stack(out, axis=1)
    print("decoded token ids (first request):", gen[0].tolist())

    # 5. checkpoint + restore: save writes the primal plus the full
    # TrainState (dual replicas, in-flight queue, step counter);
    # restore resumes the training trajectory exactly
    with tempfile.TemporaryDirectory() as d:
        session.save(d)
        print(f"checkpoint saved under {d} at step {session.steps_done}")
        restored = AMBSession.restore(d)
        assert restored.steps_done == session.steps_done
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(session.params),
                      jax.tree.leaves(restored.params)))
        assert err == 0.0, f"restore drifted: {err}"
        m = restored.run(1)     # resumes the data order at steps_done
        print(f"restored at step {restored.steps_done - 1}, "
              f"continued: loss {m['loss']:.4f}")
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
