"""Quickstart: Anytime Minibatch vs Fixed Minibatch in ~60 seconds.

Ten simulated workers (the paper's EC2 topology, lambda_2 = 0.888) learn a
10-class classifier from a synthetic stream.  Both protocols run the same
dual-averaging + consensus machinery; the only difference is AMB's fixed
compute time vs FMB's fixed batch.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (BetaSchedule, EngineConfig, ShiftedExponential,
                        amb_budget_from_fmb, run_amb, run_fmb)
from repro.core.objectives import LogisticRegression


def main():
    obj = LogisticRegression(dim=64, num_classes=10)
    means = obj.make_class_means(jax.random.PRNGKey(3))
    eval_batch = obj.sample(jax.random.PRNGKey(9), (2048,), means)
    eval_fn = lambda w: obj.loss(w, eval_batch)

    n, b_global = 10, 800
    straggler = ShiftedExponential(lam=2 / 3, zeta=1.0, b_ref=b_global // n)
    t_budget = amb_budget_from_fmb(straggler, n, b_global)  # Lemma 6
    cfg = EngineConfig(
        n=n, b_max=320, chunk=80, compute_time=t_budget,
        comm_time=0.3 * t_budget, fmb_batch_per_node=b_global // n,
        graph="paper", consensus_rounds=5,
        beta=BetaSchedule(k=1.0, mu=float(b_global)))

    kw = dict(epochs=60, key=jax.random.PRNGKey(0), sample_args=(means,),
              eval_fn=eval_fn)
    h_amb = run_amb(obj, straggler, cfg, **kw)
    h_fmb = run_fmb(obj, straggler, cfg, **kw)

    print(f"{'epoch':>5s} {'AMB wall':>9s} {'AMB loss':>9s} "
          f"{'FMB wall':>9s} {'FMB loss':>9s}")
    for t in range(0, 60, 10):
        print(f"{t:5d} {float(h_amb.wall_time[t]):9.1f} "
              f"{float(h_amb.eval_loss[t]):9.4f} "
              f"{float(h_fmb.wall_time[t]):9.1f} "
              f"{float(h_fmb.eval_loss[t]):9.4f}")
    print(f"\nAMB mean global batch b(t) = {float(h_amb.global_batch.mean()):.0f}"
          f" (FMB fixed b = {b_global}) — Lemma 6 says AMB >= FMB")
    print(f"Wall time for 60 epochs: AMB {float(h_amb.wall_time[-1]):.0f}s, "
          f"FMB {float(h_fmb.wall_time[-1]):.0f}s "
          f"({float(h_fmb.wall_time[-1] / h_amb.wall_time[-1]):.2f}x)")


if __name__ == "__main__":
    main()
