"""End-to-end driver: train a ~100M-parameter LM under AMB for a few hundred
steps on simulated devices (deliverable (b) end-to-end example).

The "demo-100m" config is a 12L/512d/32k-vocab decoder (~84M params).  Each
step draws straggler compute times, fixes the AMB budget T (Lemma 6), masks
each worker's unfinished sequences, and applies weighted consensus + dual
averaging — the full production path (pjit, FSDP x TP sharding) at CPU scale.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny   # CI-sized
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.dual_averaging import BetaSchedule           # noqa: E402
from repro.core.stragglers import (ShiftedExponential,       # noqa: E402
                                   amb_batch_sizes)
from repro.data import LMTokenStream, shard_batch            # noqa: E402
from repro.dist import use_sharding                          # noqa: E402
from repro.dist.amb import AMBConfig, make_train_step, num_workers  # noqa: E402
from repro.dist.params import tree_shardings                 # noqa: E402
from repro.metrics import MetricsLogger                      # noqa: E402
from repro.models import init_params, param_count            # noqa: E402
from repro.models.common import ArchConfig                   # noqa: E402
from repro.optim import make_optimizer                       # noqa: E402

DEMO_100M = ArchConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    qk_norm=True, q_chunk=128, kv_chunk=128,
    mxu_f32_accum=False)   # executes on CPU (no BF16xBF16=F32 dot thunk)

DEMO_TINY = ArchConfig(
    name="demo-tiny", family="dense", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
    q_chunk=64, kv_chunk=64, mxu_f32_accum=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-per-worker", type=int, default=2)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = DEMO_TINY if args.tiny else DEMO_100M
    ndev = len(jax.devices())
    data = 4 if ndev >= 8 else max(1, ndev)
    model = 2 if ndev >= 8 else 1
    mesh = jax.make_mesh((data, model), ("data", "model"))
    n = num_workers(mesh)
    gb = n * args.batch_per_worker

    key = jax.random.PRNGKey(args.seed)
    straggler = ShiftedExponential(lam=2 / 3, zeta=1.0,
                                   b_ref=args.batch_per_worker)
    t_budget = (1.0 + n / gb) * straggler.mean_batch_time()   # Lemma 6
    opt = make_optimizer("dual_averaging",
                         beta=BetaSchedule(k=30.0, mu=1.0, scale=60.0))
    stream = LMTokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           seed=args.seed)
    logger = MetricsLogger("artifacts/train_lm_demo.jsonl")

    with use_sharding(mesh):
        params = init_params(key, cfg)
        print(f"model: {cfg.name}  params={param_count(params):,}  "
              f"mesh=({data}x{model})  workers={n}  global_batch={gb}")
        params = jax.tree.map(jax.device_put, params,
                              tree_shardings(params, mesh))
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt, mesh, AMBConfig()))

        wall = 0.0
        for step in range(args.steps):
            times = straggler.per_gradient_times(
                jax.random.fold_in(key, 7000 + step), n,
                args.batch_per_worker)
            b = amb_batch_sizes(times, t_budget)
            wall += t_budget + 0.3 * t_budget
            batch = shard_batch(stream.batch(0, step, gb), mesh)
            t0 = time.time()
            params, opt_state, m = step_fn(params, opt_state, batch, b)
            loss = float(m["loss"])
            logger.log(step, loss=loss, b=float(m["global_batch"]),
                       sim_wall=wall, step_s=time.time() - t0)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {loss:.4f}  "
                      f"b(t)={int(m['global_batch'])}/{gb}  "
                      f"({time.time() - t0:.1f}s/step)")
    logger.close()
    print("done — metrics in artifacts/train_lm_demo.jsonl")


if __name__ == "__main__":
    main()
